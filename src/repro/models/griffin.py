"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local-MQA attention,
repeating pattern (rec, rec, attn). Each layer = temporal block + gated MLP.

Layers are period-stacked for lax.scan (one period = the 3-layer pattern);
the non-divisible tail is unrolled. RG-LRU runs as an associative scan
(log-depth on TPU); the recurrence itself stays fp32 (DESIGN.md §5), the
projections are BBFP-quantised.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import common as C
from repro.models import ffn as F
from repro.models.partitioning import constrain
from repro.quant import linear as Q

RGLRU_C = 8.0


def _pattern_counts(cfg):
    p = cfg.griffin.pattern
    n_periods = cfg.n_layers // len(p)
    tail = cfg.n_layers - n_periods * len(p)
    return p, n_periods, tail


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _rec_init(key, cfg: C.ArchConfig) -> dict:
    g = cfg.griffin
    d, w = cfg.d_model, g.lru_width
    ks = jax.random.split(key, 6)
    return {
        "norm": C.rmsnorm_init(d, cfg.param_dtype),
        "proj_x": C.dense_init(ks[0], d, w, False, cfg.param_dtype),
        "proj_gate": C.dense_init(ks[1], d, w, False, cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[2], (g.conv_width, w)) * 0.1).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((w,), cfg.param_dtype),
        "wa": C.dense_init(ks[3], w, w, True, cfg.param_dtype),
        "wx": C.dense_init(ks[4], w, w, True, cfg.param_dtype),
        "lam": (jax.random.uniform(ks[5], (w,), minval=2.0, maxval=5.0)
                ).astype(cfg.param_dtype),  # sigmoid(lam)^c in (0.88..0.99)^8
        "proj_out": C.dense_init(ks[5], w, d, False, cfg.param_dtype),
    }


def _rglru(lp, x, qcfg, h0=None):
    """x: (B,S,W). h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t).
    Returns (y, h_last)."""
    r = jax.nn.sigmoid(Q.qlinear(lp["wa"], x, qcfg).astype(jnp.float32))
    i = jax.nn.sigmoid(Q.qlinear(lp["wx"], x, qcfg).astype(jnp.float32))
    log_a = RGLRU_C * r * jax.nn.log_sigmoid(lp["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    if h0 is not None:  # single-step decode
        h = a[:, 0] * h0 + b[:, 0]
        return h[:, None].astype(x.dtype), h
    # associative scan: (a2,b2) o (a1,b1) = (a1*a2, b1*a2 + b2)
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2
    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hs.astype(x.dtype), hs[:, -1]


def _rec_apply(lp, h, cfg, qcfg, conv_state=None, lru_state=None, decode=False):
    h = constrain(h, "batch", "seq", None)
    x = C.rmsnorm(lp["norm"], h, cfg.norm_eps)
    branch = Q.qlinear(lp["proj_x"], x, qcfg)
    gate = jax.nn.gelu(Q.qlinear(lp["proj_gate"], x, qcfg))
    from repro.models.mamba2 import _conv1d
    branch, new_conv = _conv1d(branch, lp["conv_w"], lp["conv_b"], conv_state)
    y, h_last = _rglru(lp, branch, qcfg, h0=lru_state if decode else None)
    out = Q.qlinear(lp["proj_out"], y * gate, qcfg)
    return h + out, (new_conv, h_last)


def _attn_init(key, cfg: C.ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": A.gqa_init(k1, cfg),
    }


def _attn_apply(lp, h, cfg, qcfg, positions, cache=None, pos=None):
    h = constrain(h, "batch", "seq", None)
    x = C.rmsnorm(lp["norm"], h, cfg.norm_eps)
    out, nc = A.gqa_apply(lp["attn"], x, cfg, qcfg, positions=positions,
                          causal=True, window=cfg.griffin.window,
                          cache=cache, pos=pos)
    return h + out, nc


def _mlp_init(key, cfg):
    return {"norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "mlp": F.mlp_init(key, cfg)}


def _mlp_apply(lp, h, cfg, qcfg):
    return h + F.mlp_apply(lp["mlp"], C.rmsnorm(lp["norm"], h, cfg.norm_eps), cfg, qcfg)


def _period_init(key, cfg) -> dict:
    pat, _, _ = _pattern_counts(cfg)
    p = {}
    ks = jax.random.split(key, 2 * len(pat))
    for j, kind in enumerate(pat):
        tinit = _rec_init if kind == "rec" else _attn_init
        p[f"t{j}"] = tinit(ks[2 * j], cfg)
        p[f"m{j}"] = _mlp_init(ks[2 * j + 1], cfg)
    return p


def init(cfg: C.ArchConfig, key) -> dict:
    pat, n_periods, tail = _pattern_counts(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "embed": {"w": (jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02
                        ).astype(cfg.param_dtype)},
        "periods": C.stacked_init(lambda k: _period_init(k, cfg), k2, n_periods),
        "final_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if tail:
        tks = jax.random.split(k3, tail)
        params["tail"] = [{"t": _rec_init(tks[i], cfg), "m": _mlp_init(tks[i], cfg)}
                          for i in range(tail)]  # tail layers are rec (pattern starts rec)
    if not cfg.tie_embeddings:
        params["lm_head"] = C.dense_init(k4, cfg.d_model, cfg.vocab, False, cfg.param_dtype)
    return params


def _unembed(params, cfg, h):
    if cfg.tie_embeddings:
        return h @ params["embed"]["w"].T.astype(h.dtype)
    return Q.qlinear(params["lm_head"], h, Q.FP)


# ---------------------------------------------------------------------------
# forward / decode
# ---------------------------------------------------------------------------

def _zero_states(cfg, b, kv_len):
    g = cfg.griffin
    return {
        "conv": jnp.zeros((b, g.conv_width - 1, g.lru_width), jnp.float32),
        "lru": jnp.zeros((b, g.lru_width), jnp.float32),
        "k": jnp.zeros((b, kv_len, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        "v": jnp.zeros((b, kv_len, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
    }


def forward(params, cfg: C.ArchConfig, tokens, qcfg, remat=False, cache=None):
    pat, n_periods, tail = _pattern_counts(cfg)
    h = params["embed"]["w"][tokens].astype(cfg.compute_dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(jnp.sqrt(cfg.d_model), h.dtype)
    b, s, _ = h.shape
    positions = jnp.arange(s)
    want_cache = cache is not None
    kv_len = s

    def period_body(h, pp):
        states = {}
        for j, kind in enumerate(pat):
            if kind == "rec":
                h, (conv, lru) = _rec_apply(pp[f"t{j}"], h, cfg, qcfg)
                states[f"conv{j}"], states[f"lru{j}"] = conv, lru
            else:
                kvc = {"k": jnp.zeros((b, kv_len, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
                       "v": jnp.zeros((b, kv_len, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)}
                h, nc = _attn_apply(pp[f"t{j}"], h, cfg, qcfg, positions,
                                    cache=kvc if want_cache else None)
                if want_cache:
                    states[f"k{j}"], states[f"v{j}"] = nc["k"], nc["v"]
            h = _mlp_apply(pp[f"m{j}"], h, cfg, qcfg)
        return h, states if want_cache else None

    body = jax.checkpoint(period_body) if remat else period_body
    h, period_states = jax.lax.scan(body, h, params["periods"])

    tail_states = []
    for i in range(tail):
        h, (conv, lru) = _rec_apply(params["tail"][i]["t"], h, cfg, qcfg)
        h = _mlp_apply(params["tail"][i]["m"], h, cfg, qcfg)
        tail_states.append({"conv": conv, "lru": lru})

    h = C.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _unembed(params, cfg, h)
    new_cache = None
    if want_cache:
        new_cache = {"periods": period_states,
                     "tail": tail_states,
                     "pos": jnp.asarray(s, jnp.int32)}
    return logits, new_cache, jnp.asarray(0.0, jnp.float32)


def loss_fn(params, cfg, batch, qcfg, remat=True):
    logits, _, _ = forward(params, cfg, batch["tokens"], qcfg, remat=remat)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


def init_cache(cfg: C.ArchConfig, b: int, max_len: int):
    """Attention caches are WINDOW-bounded (ring buffer) — this is what makes
    long_500k decode sub-quadratic memory for this family."""
    pat, n_periods, tail = _pattern_counts(cfg)
    g = cfg.griffin
    kv_len = min(max_len, g.window)
    per = {}
    for j, kind in enumerate(pat):
        if kind == "rec":
            per[f"conv{j}"] = jnp.zeros((n_periods, b, g.conv_width - 1, g.lru_width), jnp.float32)
            per[f"lru{j}"] = jnp.zeros((n_periods, b, g.lru_width), jnp.float32)
        else:
            per[f"k{j}"] = jnp.zeros((n_periods, b, kv_len, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)
            per[f"v{j}"] = jnp.zeros((n_periods, b, kv_len, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)
    return {
        "periods": per,
        "tail": [{"conv": jnp.zeros((b, g.conv_width - 1, g.lru_width), jnp.float32),
                  "lru": jnp.zeros((b, g.lru_width), jnp.float32)} for _ in range(tail)],
        "pos": jnp.asarray(0, jnp.int32),
    }


def prefill(params, cfg, tokens, qcfg, max_len=None, vis_embed=None):
    """Prefill via forward; attention KV clipped to the window for decode."""
    b, s = tokens.shape
    logits, fwd_cache, _ = forward(params, cfg, tokens, qcfg, cache={})
    pat, n_periods, tail = _pattern_counts(cfg)
    g = cfg.griffin
    max_len = max_len or s
    cache = init_cache(cfg, b, max_len)
    kv_len = min(max_len, g.window)
    for j, kind in enumerate(pat):
        if kind == "rec":
            cache["periods"][f"conv{j}"] = fwd_cache["periods"][f"conv{j}"]
            cache["periods"][f"lru{j}"] = fwd_cache["periods"][f"lru{j}"]
        else:
            # keep the last `window` positions, written at slot = pos % window
            k_full = fwd_cache["periods"][f"k{j}"]
            v_full = fwd_cache["periods"][f"v{j}"]
            take = min(s, kv_len)
            src = jnp.arange(s - take, s)
            dst = src % kv_len
            cache["periods"][f"k{j}"] = cache["periods"][f"k{j}"].at[:, :, dst].set(k_full[:, :, src])
            cache["periods"][f"v{j}"] = cache["periods"][f"v{j}"].at[:, :, dst].set(v_full[:, :, src])
    for i in range(tail):
        cache["tail"][i] = fwd_cache["tail"][i]
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return logits[:, -1], cache


def decode_step(params, cfg, cache, tokens, qcfg):
    if jnp.ndim(cache["pos"]):
        raise NotImplementedError(
            "griffin decode is sequence-synchronous: conv/LRU states carry no "
            "per-slot time index, so ragged per-slot positions (pos vector) "
            "are unsupported — pad the batch to a common length instead")
    pat, n_periods, tail = _pattern_counts(cfg)
    g = cfg.griffin
    pos = cache["pos"]
    h = params["embed"]["w"][tokens].astype(cfg.compute_dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(jnp.sqrt(cfg.d_model), h.dtype)
    positions = jnp.asarray(pos).reshape(1)
    kv_len = jax.tree.leaves({k: v for k, v in cache["periods"].items() if k.startswith("k")})
    kv_len = kv_len[0].shape[2] if kv_len else g.window

    def body(h, xs):
        pp, pc = xs
        new_states = {}
        for j, kind in enumerate(pat):
            if kind == "rec":
                h, (conv, lru) = _rec_apply(pp[f"t{j}"], h, cfg, qcfg,
                                            conv_state=pc[f"conv{j}"],
                                            lru_state=pc[f"lru{j}"], decode=True)
                new_states[f"conv{j}"], new_states[f"lru{j}"] = conv, lru
            else:
                # ring-buffer write at pos % kv_len; all slots <= pos valid
                slot = pos % kv_len
                kvc = {"k": pc[f"k{j}"], "v": pc[f"v{j}"]}
                x = C.rmsnorm(pp[f"t{j}"]["norm"], h, cfg.norm_eps)
                out, nc = A.gqa_apply(pp[f"t{j}"]["attn"], x, cfg, qcfg,
                                      positions=positions, causal=False,
                                      window=None, cache=kvc, pos=slot,
                                      ring_positions=(pos, kv_len))
                h = h + out
                new_states[f"k{j}"], new_states[f"v{j}"] = nc["k"], nc["v"]
            h = _mlp_apply(pp[f"m{j}"], h, cfg, qcfg)
        return h, new_states

    h, new_period_states = jax.lax.scan(body, h, (params["periods"], cache["periods"]))

    new_tail = []
    for i in range(tail):
        h, (conv, lru) = _rec_apply(params["tail"][i]["t"], h, cfg, qcfg,
                                    conv_state=cache["tail"][i]["conv"],
                                    lru_state=cache["tail"][i]["lru"], decode=True)
        h = _mlp_apply(params["tail"][i]["m"], h, cfg, qcfg)
        new_tail.append({"conv": conv, "lru": lru})

    h = C.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _unembed(params, cfg, h)[:, 0]
    return logits, {"periods": new_period_states, "tail": new_tail, "pos": pos + 1}
