"""Mamba-2 (SSD — state-space duality), chunked training + O(1) decode.

Per layer: in_proj -> [z | xBC | dt]; causal conv(4) + SiLU on xBC;
SSD scan over heads (scalar decay per head, state (P x N));
y = SSD(x,B,C) + D*x;  out = out_proj(rmsnorm(y * silu(z))).

BBFP applicability (DESIGN.md §5): projections and the intra-chunk GEMMs
(C B^T and the score@x contraction) are block GEMMs -> quantised; the
inter-chunk state recurrence stays fp32 (no block-GEMM structure).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models.partitioning import constrain
from repro.quant import linear as Q


def _dims(cfg: C.ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def layer_init(key, cfg: C.ArchConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "norm": C.rmsnorm_init(d, cfg.param_dtype),
        "in_proj": C.dense_init(ks[0], d, 2 * d_inner + 2 * s.n_groups * s.d_state + h,
                                False, cfg.param_dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_dim)) * 0.1
                   ).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(cfg.param_dtype),
        "D": jnp.ones((h,), cfg.param_dtype),
        "dt_bias": jnp.zeros((h,), cfg.param_dtype),
        "gate_norm": C.rmsnorm_init(d_inner, cfg.param_dtype),
        "out_proj": C.dense_init(ks[2], d_inner, d, False, cfg.param_dtype),
    }


def init(cfg: C.ArchConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": {"w": (jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02
                        ).astype(cfg.param_dtype)},
        "layers": C.stacked_init(lambda k: layer_init(k, cfg), k2, cfg.n_layers),
        "final_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "lm_head": C.dense_init(k3, cfg.d_model, cfg.vocab, False, cfg.param_dtype),
    }


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, h, _ = _dims(cfg)
    gN = s.n_groups * s.d_state
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * gN]
    dt = zxbcdt[..., 2 * d_inner + 2 * gN:]
    return z, xBC, dt


def _conv1d(xBC, w, b, state=None):
    """Causal depthwise conv along seq. xBC: (B,S,C); w: (W,C).
    state: (B,W-1,C) previous inputs (decode)."""
    wdt = xBC.dtype
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], width - 1, xBC.shape[-1]), wdt)
        xp = jnp.concatenate([pad, xBC], axis=1)
    else:
        xp = jnp.concatenate([state.astype(wdt), xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i].astype(wdt) for i in range(width))
    new_state = xp[:, -(width - 1):]
    return jax.nn.silu(out + b.astype(wdt)), new_state


def _ssd_chunked(x, Bm, Cm, dt, A, chunk, qcfg, h_init=None):
    """SSD scan. x:(B,S,H,P), Bm/Cm:(B,S,N) (ngroups=1), dt:(B,S,H), A:(H,)>0.
    Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    b, s_len, h, p = x.shape
    n = Bm.shape[-1]
    nc = s_len // chunk
    assert s_len % chunk == 0, (s_len, chunk)
    xr = x.reshape(b, nc, chunk, h, p)
    Br = Bm.reshape(b, nc, chunk, n)
    Cr = Cm.reshape(b, nc, chunk, n)
    dtr = dt.reshape(b, nc, chunk, h)
    # per-step log decay (negative): l_t = -dt_t * A
    ldec = -dtr * A[None, None, None, :]                     # (B,nc,Q,H)
    cum = jnp.cumsum(ldec, axis=2)                            # inclusive
    h0 = h_init if h_init is not None else jnp.zeros((b, h, p, n), jnp.float32)

    def body(hprev, idx):
        xb = xr[:, idx]
        Bb, Cb, dtb = Br[:, idx], Cr[:, idx], dtr[:, idx]
        cumb = cum[:, idx]                                    # (B,Q,H)
        # intra-chunk: scores[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s, s<=t
        cbq = Q.qact(Cb.astype(jnp.float32), qcfg, axis=-1)
        bbq = Q.qact(Bb.astype(jnp.float32), qcfg, axis=-1)
        dots = jnp.einsum("btn,bsn->bts", cbq, bbq)           # (B,Q,Q)
        ldiff = cumb[:, :, None, :] - cumb[:, None, :, :]     # (B,t,s,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        # mask BEFORE exp: above-diagonal ldiff is positive and can overflow,
        # and grad(where(exp(inf))) = NaN
        ldiff = jnp.where(causal[None, :, :, None], ldiff, -1e30)
        gamma = jnp.exp(ldiff)
        w_ts = dots[..., None] * gamma * dtb[:, None, :, :]   # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w_ts, xb.astype(jnp.float32))
        # inter-chunk: y_t += C_t . (exp(cum_t) * h_prev)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", cbq, hprev, jnp.exp(cumb))
        # state update: h = exp(cum_end) h_prev + sum_s exp(cum_end - cum_s) dt_s B_s x_s^T
        dec_end = jnp.exp(cumb[:, -1])                        # (B,H)
        carry_w = jnp.exp(cumb[:, -1:, :] - cumb) * dtb       # (B,Q,H)
        h_new = (hprev * dec_end[:, :, None, None]
                 + jnp.einsum("bsh,bsn,bshp->bhpn", carry_w, bbq, xb.astype(jnp.float32)))
        return h_new, (y_intra + y_inter).astype(x.dtype)

    h_fin, ys = jax.lax.scan(body, h0, jnp.arange(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_len, h, p)
    return y, h_fin


def _layer_apply(lp, h_res, cfg, qcfg, conv_state=None, ssm_state=None):
    """Full-sequence layer. Returns (h, (conv_state, ssm_state))."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    h_res = constrain(h_res, "batch", "seq", None)
    x_in = C.rmsnorm(lp["norm"], h_res, cfg.norm_eps)
    zxbcdt = Q.qlinear(lp["in_proj"], x_in, qcfg)
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC, new_conv = _conv1d(xBC, lp["conv_w"], lp["conv_b"], conv_state)
    xs = xBC[..., :d_inner].reshape(*xBC.shape[:2], nheads, s.head_dim)
    Bm = xBC[..., d_inner:d_inner + s.d_state]
    Cm = xBC[..., d_inner + s.d_state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = jnp.exp(lp["A_log"].astype(jnp.float32))
    y, h_fin = _ssd_chunked(xs, Bm, Cm, dt, A, min(s.chunk, xs.shape[1]), qcfg,
                            h_init=ssm_state)
    y = y + xs * lp["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(*y.shape[:2], d_inner)
    y = C.rmsnorm(lp["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = Q.qlinear(lp["out_proj"], y, qcfg)
    return h_res + out, (new_conv, h_fin)


def forward(params, cfg: C.ArchConfig, tokens, qcfg, remat=False, cache=None):
    h = params["embed"]["w"][tokens].astype(cfg.compute_dtype)

    def body(carry, lp):
        h = carry
        h, states = _layer_apply(lp, h, cfg, qcfg)
        return h, states if cache is not None else None

    scan_body = jax.checkpoint(body) if remat else body
    h, states = jax.lax.scan(scan_body, h, params["layers"])
    h = C.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = Q.qlinear(params["lm_head"], h, Q.FP)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": states[0], "state": states[1],
                     "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    return logits, new_cache, jnp.asarray(0.0, jnp.float32)


def loss_fn(params, cfg, batch, qcfg, remat=True):
    logits, _, _ = forward(params, cfg, batch["tokens"], qcfg, remat=remat)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


def init_cache(cfg: C.ArchConfig, b: int, max_len: int):
    s = cfg.ssm
    d_inner, h, conv_dim = _dims(cfg)
    L = cfg.n_layers
    return {
        "conv": jnp.zeros((L, b, s.conv_width - 1, conv_dim), jnp.float32),
        "state": jnp.zeros((L, b, h, s.head_dim, s.d_state), jnp.float32),
        "pos": jnp.asarray(0, jnp.int32),
    }


def prefill(params, cfg, tokens, qcfg, max_len=None, vis_embed=None):
    logits, cache, _ = forward(params, cfg, tokens, qcfg,
                               cache=init_cache(cfg, tokens.shape[0], 0))
    return logits[:, -1], cache


def decode_step(params, cfg, cache, tokens, qcfg):
    """One step: state update h = a h + dt B x^T per head. tokens (B,1)."""
    if jnp.ndim(cache["pos"]):
        raise NotImplementedError(
            "mamba2 decode is sequence-synchronous: the SSM state has no "
            "per-slot time index, so ragged per-slot positions (pos vector) "
            "are unsupported — pad the batch to a common length instead")
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    h = params["embed"]["w"][tokens].astype(cfg.compute_dtype)  # (B,1,d)

    def body(h, xs):
        lp, conv_st, ssm_st = xs
        x_in = C.rmsnorm(lp["norm"], h, cfg.norm_eps)
        zxbcdt = Q.qlinear(lp["in_proj"], x_in, qcfg)
        z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
        xBC, new_conv = _conv1d(xBC, lp["conv_w"], lp["conv_b"], conv_st)
        xs_ = xBC[..., :d_inner].reshape(-1, nheads, s.head_dim)      # (B,H,P)
        Bm = xBC[:, 0, d_inner:d_inner + s.d_state]                   # (B,N)
        Cm = xBC[:, 0, d_inner + s.d_state:]
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
        A = jnp.exp(lp["A_log"].astype(jnp.float32))
        a = jnp.exp(-dt * A)                                          # (B,H)
        h_new = (ssm_st * a[:, :, None, None]
                 + jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32),
                              xs_.astype(jnp.float32)))
        y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h_new)
        y = y + xs_.astype(jnp.float32) * lp["D"].astype(jnp.float32)[None, :, None]
        y = y.reshape(-1, 1, d_inner).astype(h.dtype)
        y = C.rmsnorm(lp["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
        out = Q.qlinear(lp["out_proj"], y, qcfg)
        return h + out, (new_conv, h_new)

    h, states = jax.lax.scan(body, h, (params["layers"], cache["conv"], cache["state"]))
    h = C.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = Q.qlinear(params["lm_head"], h, Q.FP)[:, 0]
    return logits, {"conv": states[0], "state": states[1], "pos": cache["pos"] + 1}
