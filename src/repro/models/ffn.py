"""FFN: gated MLP (SiLU/GELU via the LUT unit) and sort-based MoE dispatch.

MoE = expert-parallel friendly: top-k routing, sort tokens by expert,
capacity-bounded gather -> batched expert GEMM -> weighted scatter-add.
On the production mesh the expert dim is sharded over "model", so the
gather/scatter lower to all-to-all — the EP pattern we want in the HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.quant import linear as Q


def mlp_init(key, cfg: C.ArchConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": C.dense_init(ks[0], d, f, False, cfg.param_dtype),
        "w_up": C.dense_init(ks[1], d, f, False, cfg.param_dtype),
        "w_down": C.dense_init(ks[2], f, d, False, cfg.param_dtype),
    }


def mlp_apply(params, x, cfg: C.ArchConfig, qcfg: Q.QuantConfig) -> jax.Array:
    xq, pre = Q.qact_shared(x, qcfg)          # gate+up share one quantisation
    g = Q.qlinear(params["w_gate"], xq, qcfg, x_prequantized=pre)
    act = Q.qsilu(g, qcfg) if cfg.act == "silu" else Q.qgelu(g, qcfg)
    h = act * Q.qlinear(params["w_up"], xq, qcfg, x_prequantized=pre)
    return Q.qlinear(params["w_down"], h, qcfg)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_init(key, cfg: C.ArchConfig) -> dict:
    m = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_expert
    ks = jax.random.split(key, 5)
    init = lambda k, shape, fan: (jax.random.normal(k, shape) / jnp.sqrt(fan)).astype(cfg.param_dtype)
    p = {
        "router": {"w": init(ks[0], (d, e), d).astype(jnp.float32)},
        "w_gate": init(ks[1], (e, d, f), d),
        "w_up": init(ks[2], (e, d, f), d),
        "w_down": init(ks[3], (e, f, d), f),
    }
    if m.n_shared:
        p["shared"] = mlp_init(ks[4], cfg, m.d_shared * m.n_shared)
    return p


def _moe_dispatch_compute(x2, router_w, w_gate, w_up, w_down,
                          cfg: C.ArchConfig, qcfg: Q.QuantConfig,
                          dropless: bool) -> jax.Array:
    """Sort-based capacity dispatch + expert GEMMs on a (T, d) token block.
    Pure local compute — no collectives; callers decide the distribution."""
    m = cfg.moe
    t, d = x2.shape
    k, e = m.top_k, m.n_experts
    cap = t * k if dropless else int(max(1, round(t * k / e * m.capacity_factor)))

    # --- routing (fp32 for stability; router excluded from quantisation) ---
    logits = x2.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                      # (T,k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)

    # --- sort-based dispatch ---
    flat_e = top_i.reshape(-1)                                  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    starts = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = jnp.arange(t * k) - starts[se]
    keep = pos < cap
    dest = jnp.where(keep, se * cap + pos, e * cap)             # overflow slot

    buf = jnp.zeros((e * cap + 1, d), x2.dtype).at[dest].set(x2[st])
    hbuf = buf[: e * cap].reshape(e, cap, d)

    def expert_gemm(hq, w, pre):                                # (E,C,din)x(E,din,f)
        if not pre:
            hq = Q.qact(hq, qcfg, axis=-1)
        if isinstance(w, dict):  # packed serving weights (quant.packed)
            from repro.core import bbfp as B
            wq = B.unpack_weight(w, out_dtype=hq.dtype)
        else:
            wq = Q.qweight(w.astype(hq.dtype), qcfg, axis=1)
        return jnp.einsum("ecd,edf->ecf", hq, wq)

    hbuf_q, pre = Q.qact_shared(hbuf, qcfg)    # gate+up share one quantisation
    g = expert_gemm(hbuf_q, w_gate, pre)
    act = Q.qsilu(g, qcfg) if cfg.act == "silu" else Q.qgelu(g, qcfg)
    hmid = act * expert_gemm(hbuf_q, w_up, pre)
    out_e = expert_gemm(hmid, w_down, False)                    # (E,C,d)

    out_flat = out_e.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], out_flat[jnp.clip(dest, 0, e * cap - 1)], 0.0)
    return jnp.zeros((t, d), x2.dtype).at[st].add(
        gathered * sp[:, None].astype(x2.dtype))


def _moe_shardmap_ok(cfg, t):
    """§Perf B/H1 gate: local-dispatch shard_map path available?"""
    from repro.models.partitioning import _CTX
    from repro.perf_flags import enabled
    mesh = _CTX["mesh"]
    if mesh is None or not enabled("moe_shardmap"):
        return None
    if "model" not in mesh.axis_names or mesh.shape["model"] <= 1:
        return None
    if cfg.moe.n_experts % mesh.shape["model"] != 0 or t % mesh.size != 0:
        return None
    return mesh


def moe_apply(params, x, cfg: C.ArchConfig, qcfg: Q.QuantConfig,
              dropless: bool = False) -> jax.Array:
    """x: (B,S,d) -> (B,S,d).

    Distribution (§Perf iteration B/H1): under a bound mesh the dispatch runs
    inside shard_map with tokens sharded over EVERY mesh axis and the expert
    bank all-gathered over "model" per layer. Rationale: GSPMD lowers the
    data-dependent scatter of a globally-sharded dispatch to full-buffer
    all-reduces (measured 12.4 TB/chip on qwen3-moe prefill_32k); gathering
    the (small-expert) weights instead moves ~1.2 GB/layer and keeps every
    gather/scatter chip-local. Dropless decode keeps capacity = T*k.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    x2 = x.reshape(t, d)
    mesh = _moe_shardmap_ok(cfg, t)

    if mesh is None:
        combined = _moe_dispatch_compute(
            x2, params["router"]["w"], params["w_gate"], params["w_up"],
            params["w_down"], cfg, qcfg, dropless)
    else:
        from jax.sharding import PartitionSpec as P
        axes = tuple(mesh.axis_names)
        tok = P(axes, None)
        wspec = jax.tree.map(lambda _: P("model"), params["w_gate"])  # E-dim sharded

        def inner(x_loc, rw, wg, wu, wd):
            gather = lambda w: jax.tree.map(
                lambda a: jax.lax.all_gather(a, "model", axis=0, tiled=True), w)
            return _moe_dispatch_compute(
                x_loc, rw, gather(wg), gather(wu), gather(wd), cfg, qcfg, dropless)

        combined = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(tok, P(None, None), wspec, wspec, wspec),
            out_specs=tok, check_vma=False,
        )(x2, params["router"]["w"], params["w_gate"], params["w_up"],
          params["w_down"])

    if m.n_shared:
        combined = combined + mlp_apply(params["shared"], x2, cfg, qcfg)
    return combined.reshape(b, s, d)


def moe_aux_loss(params, x, cfg: C.ArchConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_i * P_i)."""
    m = cfg.moe
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    probs = jax.nn.softmax(x2 @ params["router"]["w"], axis=-1)
    top_i = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_i, m.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return m.n_experts * jnp.sum(frac * imp)
