"""Shared model plumbing: ArchConfig, param init helpers, norms, RoPE."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden dim
    n_shared: int = 0           # shared (always-on) experts
    d_shared: int = 0           # hidden dim of the shared expert MLP
    capacity_factor: float = 1.25
    first_dense: int = 0        # leading layers that use a dense FFN instead
    d_ff_dense: int = 0         # hidden dim of those dense layers


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:                 # Mamba-2 SSD
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class GriffinConfig:             # RecurrentGemma
    lru_width: int = 2560
    conv_width: int = 4
    window: int = 2048
    pattern: tuple = ("rec", "rec", "attn")   # repeating block pattern


@dataclasses.dataclass(frozen=True)
class EncoderConfig:             # whisper-style encoder (frontend stubbed)
    n_layers: int = 4
    n_frames: int = 1500         # precomputed frame embeddings (stub)
    max_dec_pos: int = 32768     # learned decoder position table size


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # decoder | mamba2 | griffin | whisper
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    act: str = "silu"            # silu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False    # gemma: embeddings scaled by sqrt(d)
    post_norm: bool = False      # gemma3 sandwich norms
    # local/global attention: window size + period ("5:1" -> every 6th global)
    sliding_window: int = 0      # 0 = all-global
    global_every: int = 0        # 0 = all layers local (if window) / all global
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    griffin: Optional[GriffinConfig] = None
    encoder: Optional[EncoderConfig] = None
    vis_len: int = 0             # VLM: number of stub patch embeddings
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def layer_is_global(self, i: int) -> bool:
        """Attention span of layer i under the local:global pattern."""
        if self.sliding_window == 0:
            return True
        if self.global_every == 0:
            return False
        return (i % self.global_every) == (self.global_every - 1)

    def param_count(self) -> int:
        """Exact parameter count from the init shapes."""
        from repro.models import model as M
        shapes = jax.eval_shape(lambda k: M.init(self, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


# ---------------------------------------------------------------------------
# small functional layers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> dict:
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def rope_tables(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """cos/sin tables for positions (...,). Returns (cos, sin) (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (S, D/2) (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)   # (S, 1, D/2) broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def stacked_init(fn, key, n: int):
    """vmap a per-layer init over n layers -> stacked params for lax.scan."""
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)
