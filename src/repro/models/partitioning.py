"""Logical-axis activation sharding constraints.

Models call ``constrain(x, "batch", None, None)`` with *logical* axis names;
the launcher binds logical axes to mesh axes for the current step kind
(train / serve / long-context). Without a bound context, constrain is a
no-op — models stay mesh-agnostic and run everywhere.

Why this exists: with FSDP-sharded weights (d over "data") and batch-sharded
inputs, GSPMD's cost model sometimes prefers resharding the *activations*
onto the weight layout (replicating the batch!) over all-gathering weights.
Anchoring the residual stream to P(batch-axes, ...) at layer boundaries pins
the intended ZeRO-3 strategy (verified: 5x per-chip FLOP reduction on the
internlm2 train cell).
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: dict = {"mesh": None, "rules": {}}

# default logical-axis bindings per step kind. "pages" is the physical
# page-pool dim of fused-path paged KV (flash-decoding sequence
# parallelism): it rides the SAME mesh axis as tensor parallelism, so the
# fused dispatch splits pages while params stay TP-sharded on one mesh.
TRAIN_RULES = {"batch": ("pod", "data"), "heads": "model", "ff": "model",
               "seq": None, "vocab": "model", "embed": None}
SERVE_RULES = {"batch": ("pod", "data"), "heads": "model", "ff": "model",
               "seq": None, "vocab": "model", "embed": None,
               "pages": "model"}
LONG_RULES = {"batch": None, "heads": "model", "ff": "model",
              "seq": "data", "vocab": "model", "embed": None}


@contextmanager
def activation_sharding(mesh, rules: dict):
    """Bind mesh + logical rules for the duration of a trace."""
    old = dict(_CTX)
    _CTX["mesh"], _CTX["rules"] = mesh, rules
    try:
        yield
    finally:
        _CTX.update(old)


def resolve(mesh, rules, *logical) -> P:
    parts = []
    for name in logical:
        if name is None:
            parts.append(None)
            continue
        ax = rules.get(name)
        if ax is None:
            parts.append(None)
            continue
        if isinstance(ax, tuple):
            ax = tuple(a for a in ax if a in mesh.axis_names)
            parts.append(ax if len(ax) > 1 else (ax[0] if ax else None))
        else:
            parts.append(ax if ax in mesh.axis_names else None)
    return P(*parts)


def bound_mesh():
    """The mesh bound by the enclosing ``activation_sharding`` context, or
    None outside one. The fused paged-attention dispatch uses this to decide
    between the plain kernel call and the page-sharded shard_map wrapper —
    models stay mesh-agnostic; only the bound context carries the mesh."""
    return _CTX["mesh"]


def constrain(x, *logical):
    """with_sharding_constraint by logical axis names (no-op w/o context).
    A logical dim is applied only when the dim size divides the axis size."""
    mesh, rules = _CTX["mesh"], _CTX["rules"]
    if mesh is None:
        return x
    spec = resolve(mesh, rules, *logical)
    # divisibility guard: drop axes that don't divide
    fixed = []
    for dim, part in enumerate(spec):
        if part is None:
            fixed.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(part if x.shape[dim] % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
