"""Attention: GQA (with local/global windows), MLA (DeepSeek), caches.

Three execution regimes, all quant-aware:
  * full     — materialised scores; used when S_kv <= FULL_ATTN_MAX. The
               softmax goes through the paper's segmented-LUT unit when
               qcfg.nonlinear is set.
  * chunked  — two-level online softmax (q-chunks x kv-chunks) for long
               prefill; O(chunk^2) activation memory. exp() still comes from
               the LUT unit; the running rescale stays fp32.
  * decode   — single query position against a pre-allocated cache, written
               at `pos` via dynamic_update_slice.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core import bbfp as B
from repro.models import common as C
from repro.models import partitioning as PT
from repro.quant import linear as Q

FULL_ATTN_MAX = 4096
Q_CHUNK = 2048
KV_CHUNK = 1024


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: C.ArchConfig) -> dict:
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": C.dense_init(ks[0], d, h * hd, cfg.qkv_bias, cfg.param_dtype),
        "wk": C.dense_init(ks[1], d, kh * hd, cfg.qkv_bias, cfg.param_dtype),
        "wv": C.dense_init(ks[2], d, kh * hd, cfg.qkv_bias, cfg.param_dtype),
        "wo": C.dense_init(ks[3], h * hd, d, False, cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = C.rmsnorm_init(hd, cfg.param_dtype)
        p["k_norm"] = C.rmsnorm_init(hd, cfg.param_dtype)
    return p


def mla_init(key, cfg: C.ArchConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "wq": C.dense_init(ks[0], d, h * (m.qk_nope_dim + m.qk_rope_dim),
                           False, cfg.param_dtype),
        "w_dkv": C.dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_dim,
                              False, cfg.param_dtype),
        "ckv_norm": C.rmsnorm_init(m.kv_lora_rank, cfg.param_dtype),
        "w_uk": C.dense_init(ks[2], m.kv_lora_rank, h * m.qk_nope_dim,
                             False, cfg.param_dtype),
        "w_uv": C.dense_init(ks[3], m.kv_lora_rank, h * m.v_head_dim,
                             False, cfg.param_dtype),
        "wo": C.dense_init(ks[4], h * m.v_head_dim, d, False, cfg.param_dtype),
    }


# ---------------------------------------------------------------------------
# score/mask helpers
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, causal: bool, window) -> jax.Array:
    """(..., Sq, Sk) bool validity mask. q_pos/k_pos may carry a leading
    batch dim (per-slot ragged positions). window: 0/None = unbounded."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window          # window is traced-scalar friendly
    return m


def _score_mask(m: jax.Array) -> jax.Array:
    """Broadcast a (...,Sq,Sk) validity mask to score rank (B,KH,G,Sq,Sk)."""
    return m[:, None, None] if m.ndim == 3 else m[None, None, None]


def _paged_append(pool, block_table, pos, rows, kv_fmt=None, *,
                  page_axis: bool = False):
    """Scatter each slot's new rows (B, S, ...) — S consecutive KV rows
    starting at the slot's offset pos (B,) — into a page pool (n_pages,
    page, ...) at (block_table[b, (pos+i)//page], (pos+i) % page). S=1 is
    the decode append; S=chunk is incremental chunked prefill (the B rows
    may be DIFFERENT requests at different offsets — batched multi-slot
    prefill scatters them all in one call, and because this append runs
    before the gather in every layer, one batch row's writes are visible
    to another's reads within the same call). Sentinel
    table entries (= n_pages) land out of bounds and are DROPPED — idle
    slots never corrupt another slot's page — and target rows past the
    table's extent (tail-chunk padding) are redirected to the sentinel.

    A PACKED pool (dict {"q", "exp"}, see paged_kv.init_paged_cache
    storage="packed") quantises the rows on scatter: int8 codes + int8
    per-32-block shared exponents in `kv_fmt` (= qcfg.kv_fmt). Exact for
    rows already on the format grid (the qkv_cache write path). A PACKED4
    pool (same dict, q leaf half-width — two nibble codes per byte) is
    recognised by that width and encodes via ``pack_kv_nibble``."""
    if isinstance(pool, dict):
        nib = pool["q"].shape[-1] != rows.shape[-1]          # packed4 q leaf
        enc = (B.pack_kv_nibble if nib else B.pack_kv)(
            rows.astype(jnp.float32), kv_fmt)
        return {"q": _paged_append(pool["q"], block_table, pos, enc["q"],
                                   page_axis=page_axis),
                "exp": _paged_append(pool["exp"], block_table, pos,
                                     enc["exp"], page_axis=page_axis)}
    pv = jnp.asarray(pos)
    assert pv.ndim == 1, "paged caches require per-slot pos (B,)"
    page = pool.shape[1]
    rpos = pv[:, None] + jnp.arange(rows.shape[1])          # (B,S) target rows
    idx = rpos // page
    max_pages = block_table.shape[1]
    pg = jnp.take_along_axis(block_table, jnp.minimum(idx, max_pages - 1),
                             axis=1)
    pg = jnp.where(idx < max_pages, pg, pool.shape[0])      # past table: drop
    new = pool.at[pg, rpos % page].set(rows, mode="drop")
    if new.ndim == 4:
        if page_axis:
            # fused path under page-dim sharding: pin the POOL dim to the
            # TP axis so the scatter output keeps the flash-decoding page
            # sharding — constraining KH here would reshard the whole pool
            # onto the head layout every layer
            new = PT.constrain(new, "pages", None, None, None)
        else:
            # GQA pool (n_pages, page, KH, hd): pin the KV-heads dim to the
            # TP axis so a head-sharded pool stays sharded through the
            # scatter (no-op without a bound mesh; MLA's ndim-3 pools stay
            # replicated)
            new = PT.constrain(new, None, None, "heads", None)
    return new


def _paged_view(pool, block_table, kv_fmt=None, dtype=None, nibble=False):
    """Gather each slot's pages into a contiguous (B, max_pages*page, ...)
    view. Sentinel entries CLAMP to the last page; the caller's per-slot
    position mask discards those rows. A PACKED pool gathers the int8
    codes + exponents and dequantises into `dtype` — HBM only ever streams
    the 8.25-bit storage; the fp view exists in registers/VMEM only.
    `nibble=True` decodes a packed4 pool (q leaf = two codes per byte) —
    the jnp fallback the fused kernel is parity-tested against."""
    if isinstance(pool, dict):
        # §Perf: ONE block-table gather instead of two. Codes and per-block
        # exponents are both int8 and page-shaped, so they stack along the
        # trailing axis into a single (n_pages, page, ..., hdq+nb) view and
        # one gather fetches both; the split slices fuse into the consumer.
        # The stack itself is an int8 concat (~half the bytes of the bf16
        # view this path materialises anyway) — the real fix for the
        # per-tick re-materialisation is the fused kernel, not this path.
        hdq = pool["q"].shape[-1]
        both = _paged_view(jnp.concatenate([pool["q"], pool["exp"]], axis=-1),
                           block_table)
        enc = {"q": both[..., :hdq], "exp": both[..., hdq:]}
        return (B.unpack_kv_nibble if nibble else B.unpack_kv)(
            enc, kv_fmt, out_dtype=dtype)
    b = block_table.shape[0]
    out = pool[block_table].reshape(b, -1, *pool.shape[2:])
    if out.ndim == 4:
        # gathered GQA view (B, rows, KH, hd): keep it head-sharded — each
        # TP shard gathers only its own heads' pages, and the attention
        # einsums downstream contract per-head, so no resharding happens
        out = PT.constrain(out, None, None, "heads", None)
    return out if dtype is None else out.astype(dtype)


def _shard_map(f, mesh, *, in_specs, out_specs):
    """shard_map across jax versions: the public ``jax.shard_map`` (newer
    releases, `check_vma`) when present, else the experimental one
    (`check_rep`). Replication checking is off either way — the fused
    merge psums to a replicated result the checker cannot see through."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            pass
    from jax.experimental.shard_map import shard_map as esm
    return esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _fused_page_sharded(q, k_pool, v_pool, block_table, pos, window, mesh, *,
                        fmt, nibble, exp_fmt):
    """Sequence-parallel fused paged attention (flash decoding over the
    page dim). Each device owns a contiguous slice of the physical page
    pool (``paged_kv.shard_paged_cache(..., shard_axis="pages")``); inside
    the shard_map every shard translates the replicated GLOBAL block table
    to its local page ids (non-local -> local sentinel, which kills the
    tile via the kernel's partials live-gate), runs the fused kernel over
    its local pool, and the per-slot online-softmax partials (m, l, acc)
    are combined with one pmax + two psums over the page axis
    (``paged_attention.merge_partials``). With one shard the merge is
    bitwise the kernel's own normalisation, so tp=1 meshes exercise the
    identical code path. q/table/pos are replicated, the output is
    replicated — covers decode (q_len=1) and chunked prefill (q_len=S)
    alike, with NO kv_heads divisibility requirement."""
    from jax.sharding import PartitionSpec as P
    from repro.kernels import paged_attention as PA
    from repro.launch.sharding import PAGE_AXIS
    from repro.runtime.paged_kv import translate_block_table

    def body(q, k_pool, v_pool, bt, pos, win):
        shard = jax.lax.axis_index(PAGE_AXIS)
        local_n = k_pool["q"].shape[0]
        lbt = translate_block_table(bt, local_n, shard)
        acc, m, l = PA.paged_attention(q, k_pool, v_pool, lbt, pos, win,
                                       fmt=fmt, nibble=nibble,
                                       exp_fmt=exp_fmt, partials=True)
        return PA.merge_partials(acc, m, l, axis_name=PAGE_AXIS).astype(q.dtype)

    fn = _shard_map(body, mesh,
                    in_specs=(P(), P(PAGE_AXIS), P(PAGE_AXIS), P(), P(), P()),
                    out_specs=P())
    return fn(q, k_pool, v_pool, jnp.asarray(block_table, jnp.int32),
              jnp.asarray(pos, jnp.int32), jnp.asarray(window, jnp.int32))


def _full_attention(q, k, v, q_pos, k_pos, causal, window, scale, qcfg):
    """q: (B,Sq,KH,G,hd); k,v: (B,Sk,KH,hd)."""
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k) * scale
    mask = _score_mask(_mask(q_pos, k_pos, causal, window))
    probs = Q.qsoftmax(scores.astype(jnp.float32), qcfg, axis=-1, where=mask)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out


def _chunked_attention(q, k, v, q_pos, k_pos, causal, window, scale, qcfg):
    """Two-level online softmax. Shapes as _full_attention; supports
    v head_dim != q head_dim (MLA) and non-divisible sequence lengths
    (padded; pad keys get position 2^30 so the causal mask kills them)."""
    b, sq_orig, kh, g, hd = q.shape
    sk_orig = k.shape[1]
    qc = min(Q_CHUNK, sq_orig)
    kc = min(KV_CHUNK, sk_orig)

    def pad_seq(x, mult, axis, pos=None):
        pad = (-x.shape[axis]) % mult
        if pad == 0:
            return x, pos
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
        if pos is not None:
            # pad positions (time is the LAST pos axis; a leading batch dim is
            # allowed) with 2^30 so the causal mask kills the pad keys
            pw = [(0, 0)] * pos.ndim
            pw[-1] = (0, pad)
            pos = jnp.pad(pos, pw, constant_values=1 << 30)
        return x, pos

    q, q_pos = pad_seq(q, qc, 1, q_pos if q_pos.ndim else None)
    k, k_pos = pad_seq(k, kc, 1, k_pos)
    v, _ = pad_seq(v, kc, 1)
    sq, sk = q.shape[1], k.shape[1]
    hd_v = v.shape[-1]
    n_qc, n_kc = sq // qc, sk // kc
    # static positions let us bound the causal/window KV range per q-chunk;
    # only sound for shared (1-D, arange-like) positions, not ragged batches
    static_pos = sq == sk and q_pos is not None and q_pos.ndim == 1

    def q_chunk_body(qi):
        qs = q_pos[..., qi * qc:(qi + 1) * qc] if q_pos.ndim else q_pos
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)

        # §Perf H1 (causal chunk skip): q-chunk qi can only see kv chunks
        # whose positions overlap [qi*qc - window + 1, (qi+1)*qc); skip the
        # rest STATICALLY -> ~2x fewer attention tiles for causal prefill.
        from repro.perf_flags import enabled
        k_lo, k_hi = 0, n_kc
        if enabled("causal_skip"):
            if static_pos and causal:
                k_hi = min(n_kc, ((qi + 1) * qc + kc - 1) // kc)
            if static_pos and window is not None and isinstance(window, int):
                k_lo = max(0, (qi * qc - window + 1) // kc)
        n_live = k_hi - k_lo

        def kv_body(carry, ki):
            m_run, l_run, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kc, kc, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kc, kc, axis=1)
            ks_ = jax.lax.dynamic_slice_in_dim(k_pos, ki * kc, kc,
                                               axis=k_pos.ndim - 1)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk).astype(jnp.float32) * scale
            msk = _score_mask(_mask(qs, ks_, causal, window))
            s = jnp.where(msk, s, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            # LUT exp on the (<=0) shifted scores; rescale stays exact fp32
            p = Q.qexp_for_online_softmax(s - m_new[..., None], qcfg)
            p = jnp.where(msk, p, 0.0)
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v.dtype), v_blk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kh, g, qc, hd_v), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                          jnp.arange(k_lo, k_hi), length=n_live)
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return jnp.einsum("bkgqd->bqkgd", out)

    outs = [q_chunk_body(i) for i in range(n_qc)]   # unrolled q chunks
    return jnp.concatenate(outs, axis=1)[:, :sq_orig].astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA forward (train / prefill / decode)
# ---------------------------------------------------------------------------

def gqa_apply(params, x, cfg: C.ArchConfig, qcfg: Q.QuantConfig, *,
              positions, causal=True, window=None, cache=None, pos=None,
              kv_override=None, ring_positions=None, block_table=None,
              paged_attn: str = "unfused"):
    """x: (B,S,d). Returns (out, new_cache).

    cache: {"k": (B,T,KH,hd), "v": ...} pre-allocated; pos: current write
    index (decode) — either a shared scalar or a per-slot (B,) vector for
    ragged continuous batching (each batch row writes/masks at its own
    position). kv_override: (k, v, k_positions) for cross-attention.
    ring_positions: (true_pos, capacity) when the cache is a ring buffer —
    `pos` is then the write SLOT and validity is true_pos-based (every live
    slot holds one of the last `capacity` positions); scalar-pos only.
    block_table: (B, max_pages) int32 when the cache is PAGED — k/v are then
    physical page pools (n_pages, page, KH, hd): each slot scatters its new
    row at (block_table[b, pos//page], pos%page) (sentinel entries land out
    of bounds and are dropped) and attention gathers the slot's pages back
    into a contiguous (B, max_pages*page) view masked at the slot's pos.
    paged_attn: "fused" routes packed paged decode/chunk-prefill attention
    through the Pallas kernel (``kernels.paged_attention``: page gather +
    BBFP dequant + flash softmax in one VMEM pass — K/V never materialise
    at bf16 width); "unfused" (default) is the gathered-dequant jnp path.
    Fused requires a packed/packed4 paged cache; fp pools always take the
    jnp path (there is nothing to dequant in-kernel).
    """
    b, s, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = cfg.q_per_kv
    dt = x.dtype

    xq, pre = Q.qact_shared(x, qcfg)          # q/k/v share one quantisation
    q = Q.qlinear(params["wq"], xq, qcfg, x_prequantized=pre).reshape(b, s, h, hd)
    if kv_override is None:
        k = Q.qlinear(params["wk"], xq, qcfg, x_prequantized=pre).reshape(b, s, kh, hd)
        v = Q.qlinear(params["wv"], xq, qcfg, x_prequantized=pre).reshape(b, s, kh, hd)
    else:
        k, v, _ = kv_override

    if cfg.qk_norm:
        q = C.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        if kv_override is None:
            k = C.rmsnorm(params["k_norm"], k, cfg.norm_eps)

    if kv_override is None and positions is not None:
        cos, sin = C.rope_tables(positions, hd, cfg.rope_theta)
        q = C.apply_rope(q, cos, sin)
        k = C.apply_rope(k, cos, sin)

    new_cache = cache
    fused, nibble, t_paged = False, False, None
    if cache is not None and kv_override is None:
        # BBFP KV cache (serving): values land on the storage grid at write.
        # A packed paged pool ({"q","exp"} leaves) skips the fake-quant —
        # _paged_append's pack_kv IS the same quantiser (unpack(pack(x)) ==
        # fake_quant(x) bitwise, tested), so encoding the raw row once is
        # numerically identical to the fp pool and avoids double-quantising
        # every write on the decode hot path.
        packed = isinstance(cache["k"], dict)
        kv_fmt = qcfg.kv_fmt if packed else None
        # packed4 pools store two nibble codes per byte: the q leaf is
        # half the head_dim wide, which is how the storage mode is known
        # here without threading a flag through the cache pytree
        nibble = packed and cache["k"]["q"].shape[-1] != hd
        fused = (packed and paged_attn == "fused" and block_table is not None
                 and pos is not None)
        if packed:
            k_st, v_st = k, v
        else:
            k_st = Q.qkv_cache(k, qcfg).astype(cache["k"].dtype)
            v_st = Q.qkv_cache(v, qcfg).astype(cache["v"].dtype)
        if pos is not None:   # decode/chunk: write this step's k/v at pos
            if block_table is not None:
                # paged cache: k/v are page pools (n_pages, page, KH, hd);
                # all s rows (1 = decode, chunk = incremental prefill)
                # scatter through the slot's block-table row
                pv = jnp.asarray(pos)
                k_pool = _paged_append(cache["k"], block_table, pv, k_st,
                                       kv_fmt, page_axis=fused)
                v_pool = _paged_append(cache["v"], block_table, pv, v_st,
                                       kv_fmt, page_axis=fused)
                new_cache = {"k": k_pool, "v": v_pool}
                page = (k_pool["q"] if packed else k_pool).shape[1]
                t_paged = block_table.shape[1] * page
                if not fused:
                    k = _paged_view(k_pool, block_table, kv_fmt, dt,
                                    nibble=nibble)
                    v = _paged_view(v_pool, block_table, kv_fmt, dt,
                                    nibble=nibble)
                k_pos = jnp.arange(t_paged)
            elif jnp.ndim(pos):   # ragged: each slot writes at its own offset
                if ring_positions is not None:
                    raise NotImplementedError(
                        "ring-buffer caches (griffin) are scalar-pos only")
                # batched scatter: B*s rows, not a full-cache rewrite.
                # mode="drop" makes a write at pos >= T a no-op (NOTE: the
                # scalar path below instead CLAMPS to row T-1 — callers must
                # keep pos < T; the batcher rejects oversized requests).
                bidx = jnp.arange(k_st.shape[0])[:, None]
                pv = jnp.asarray(pos)
                rpos = pv[:, None] + jnp.arange(s)
                k_all = cache["k"].at[bidx, rpos].set(k_st, mode="drop")
                v_all = cache["v"].at[bidx, rpos].set(v_st, mode="drop")
            else:
                k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_st, pos, axis=1)
                v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_st, pos, axis=1)
            if block_table is None:
                new_cache = {"k": k_all, "v": v_all}
                k, v = k_all.astype(dt), v_all.astype(dt)
                k_pos = jnp.arange(cache["k"].shape[1])
        else:                 # prefill: cache <- computed k/v
            new_cache = {"k": k_st, "v": v_st}
            # attention reads the STORED values (the qkv_cache grid), exactly
            # what decode and incremental chunked prefill will read back from
            # the cache — prefill attending raw k/v while every later reader
            # sees the grid would make chunked prefill non-reproducible
            k, v = k_st.astype(dt), v_st.astype(dt)
            k_pos = jnp.arange(s)
    elif kv_override is not None:
        k_pos = kv_override[2]
    else:
        k_pos = jnp.arange(s)

    q_grp = q.reshape(b, s, kh, g, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s_kv = t_paged if fused else k.shape[1]
    if fused:
        # Fused Pallas paged attention: the kernel walks the block table a
        # page at a time, decodes the int8/nibble BBFP codes in VMEM, and
        # runs the flash online softmax — the dequantised view above never
        # exists. Same mask semantics as the unfused branch below (per-row
        # qp = pos+i, eff_window, sentinel clamp + pos mask); exp comes
        # from the LUT unit when qcfg.nonlinear is set, jnp.exp otherwise.
        from repro.kernels import paged_attention as PA   # lazy: pallas dep
        eff_window = window if window is not None else s_kv + 1
        exp_fmt = None if qcfg.nonlinear == "none" else qcfg.nonlinear_fmt
        mesh = PT.bound_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            # tensor-parallel serving: run the kernel per page-pool shard
            # inside a shard_map and log-sum-exp-merge the partials —
            # flash-decoding sequence parallelism over the "model" axis
            out = _fused_page_sharded(
                q_grp, new_cache["k"], new_cache["v"], block_table,
                jnp.asarray(pos), jnp.asarray(eff_window, jnp.int32), mesh,
                fmt=kv_fmt, nibble=nibble, exp_fmt=exp_fmt)
        else:
            out = PA.paged_attention(
                q_grp, new_cache["k"], new_cache["v"], block_table,
                jnp.asarray(pos), jnp.asarray(eff_window, jnp.int32),
                fmt=kv_fmt, nibble=nibble, exp_fmt=exp_fmt)
    elif pos is not None:
        # decode: mask by per-slot pos (cache rows beyond a slot's pos are
        # garbage). valid is (T,) for scalar pos, (B,T) for ragged vectors.
        if ring_positions is not None:
            true_pos, _cap = ring_positions
            valid = k_pos <= true_pos          # slot j first written at step j
            where = valid[None, None, None, None, :]
        else:
            eff_window = window if window is not None else s_kv + 1
            pv = jnp.asarray(pos)
            if pv.ndim:
                # per-slot query rows pos+i (s=1: decode; s=chunk: prefill)
                qp = pv[:, None] + jnp.arange(s)             # (B,Sq)
                valid = (k_pos[None, None, :] <= qp[..., None]) & \
                        (k_pos[None, None, :] > qp[..., None] - eff_window)
                where = valid[:, None, None]                 # (B,1,1,Sq,Skv)
            else:
                valid = (k_pos <= pos) & (k_pos > pos - eff_window)
                where = valid[None, None, None, None, :]
        scores = jnp.einsum("bqkgd,bskd->bkgqs", q_grp, k).astype(jnp.float32) * scale
        probs = Q.qsoftmax(scores, qcfg, axis=-1, where=where)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(dt), v)
    elif s_kv <= FULL_ATTN_MAX:
        out = _full_attention(q_grp, k, v, positions if positions is not None else jnp.arange(s),
                              k_pos, causal, window, scale, qcfg)
    else:
        out = _chunked_attention(q_grp, k, v, positions if positions is not None else jnp.arange(s),
                                 k_pos, causal, window, scale, qcfg)
    out = out.reshape(b, s, h * hd).astype(dt)
    return Q.qlinear(params["wo"], out, qcfg), new_cache


# ---------------------------------------------------------------------------
# MLA forward (DeepSeek-V2): compressed-KV attention
# ---------------------------------------------------------------------------

# one-time-per-process flag for the fused-on-MLA downgrade warning below
# (tests reset it to re-arm the warning)
_MLA_FUSED_WARNED = False


def mla_apply(params, x, cfg: C.ArchConfig, qcfg: Q.QuantConfig, *,
              positions, cache=None, pos=None, block_table=None,
              paged_attn: str = "unfused"):
    """Prefill/train: materialise k,v from the compressed cache.
    Decode: absorbed form — scores directly against the (B,T,lora) cache.
    block_table: (B, max_pages) when the compressed cache is PAGED —
    ckv/krope are then page pools (n_pages, page, ...), written by scatter
    at (page, offset) and read back through a per-slot page gather.
    paged_attn: accepted for call-site symmetry with ``gqa_apply`` but
    DOWNGRADED to the jnp path — absorbed-form MLA decode contracts q into
    the latent space before scoring, which the fused GQA kernel's
    (q·k, p·v) shape cannot express, so MLA always takes the
    gathered-dequant jnp path (and ``paged_kv`` rejects storage="packed4"
    for MLA for the same reason). ``paged_attn="fused"`` warns ONCE per
    process instead of being silently swallowed; ``kv_stats``'s
    `paged_attn_effective` reports the path that actually ran."""
    global _MLA_FUSED_WARNED
    if paged_attn == "fused" and not _MLA_FUSED_WARNED:
        _MLA_FUSED_WARNED = True
        warnings.warn(
            "paged_attn='fused' has no MLA kernel — absorbed-form latent "
            "attention cannot run the fused GQA kernel; falling back to the "
            "unfused jnp path (kv_stats reports "
            "paged_attn_effective='unfused')", RuntimeWarning, stacklevel=2)
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    dt = x.dtype
    nope, rope_d, lora, vdim = m.qk_nope_dim, m.qk_rope_dim, m.kv_lora_rank, m.v_head_dim

    xq, pre = Q.qact_shared(x, qcfg)          # wq/w_dkv share one quantisation
    q = Q.qlinear(params["wq"], xq, qcfg, x_prequantized=pre).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    dkv = Q.qlinear(params["w_dkv"], xq, qcfg, x_prequantized=pre)
    ckv = C.rmsnorm(params["ckv_norm"], dkv[..., :lora], cfg.norm_eps)   # (B,S,lora)
    k_rope = dkv[..., lora:].reshape(b, s, 1, rope_d)

    cos, sin = C.rope_tables(positions, rope_d, cfg.rope_theta)
    q_rope = C.apply_rope(q_rope, cos, sin)
    k_rope = C.apply_rope(k_rope, cos, sin)[:, :, 0]                     # (B,S,rope)

    scale = 1.0 / jnp.sqrt(nope + rope_d).astype(jnp.float32)
    new_cache = cache

    if pos is not None:
        # MLA's compressed latent is NOT quantised on the fp paths: it feeds
        # both k_nope and v through learned up-projections, which amplify
        # quantisation error ~4x vs a plain KV cache (measured; DESIGN.md
        # §5). The latent is already 4.5x smaller than a GQA cache, so the
        # win is small anyway. PACKED page pools are the explicit opt-in
        # exception (kv_storage="packed"): the latent is stored as int8
        # codes in qcfg.kv_fmt — a memory/accuracy tradeoff the fp paths
        # deliberately don't take, so packed-MLA is close-but-not-equal to
        # fp-MLA (unlike GQA, where packed is exact).
        packed = isinstance(cache["ckv"], dict)
        kv_fmt = qcfg.kv_fmt if packed else None
        ckv_st = ckv if packed else ckv.astype(cache["ckv"].dtype)
        kr_st = k_rope if packed else k_rope.astype(cache["krope"].dtype)
        pv = jnp.asarray(pos)
        if block_table is not None:
            # paged compressed cache: scatter all s rows at (page, offset),
            # gather the slot's pages back into a (B, max_pages*page) view
            ckv_pool = _paged_append(cache["ckv"], block_table, pv, ckv_st, kv_fmt)
            kr_pool = _paged_append(cache["krope"], block_table, pv, kr_st, kv_fmt)
            new_cache = {"ckv": ckv_pool, "krope": kr_pool}
            ckv_all = _paged_view(ckv_pool, block_table, kv_fmt, dt)
            kr_all = _paged_view(kr_pool, block_table, kv_fmt, dt)
        elif pv.ndim:   # ragged: per-slot write offsets (B,), batched scatter
            bidx = jnp.arange(ckv_st.shape[0])[:, None]
            rpos = pv[:, None] + jnp.arange(s)
            ckv_all = cache["ckv"].at[bidx, rpos].set(ckv_st, mode="drop")
            kr_all = cache["krope"].at[bidx, rpos].set(kr_st, mode="drop")
            new_cache = {"ckv": ckv_all, "krope": kr_all}
        else:
            ckv_all = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_st, pos, axis=1)
            kr_all = jax.lax.dynamic_update_slice_in_dim(cache["krope"], kr_st, pos, axis=1)
            new_cache = {"ckv": ckv_all, "krope": kr_all}
        t = ckv_all.shape[1]
        if s > 1:
            # incremental chunked prefill: materialise k/v from the cached
            # latent exactly as the dense-prefill branch does (the absorbed
            # form below contracts in a different order and would not be
            # bit-identical to a staged prefill of the same rows)
            qp = pv[:, None] + jnp.arange(s) if pv.ndim else pos + jnp.arange(s)
            k_nope = Q.qlinear(params["w_uk"], ckv_all.astype(dt), qcfg
                               ).reshape(b, t, h, nope)
            v_all = Q.qlinear(params["w_uv"], ckv_all.astype(dt), qcfg
                              ).reshape(b, t, h, vdim)
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr_all.astype(dt)[:, :, None],
                                          (b, t, h, rope_d))], -1)
            qq = jnp.concatenate([q_nope, q_rope], -1
                                 ).reshape(b, s, h, 1, nope + rope_d)
            out = _full_attention(qq, k_full, v_all, qp, jnp.arange(t),
                                  True, None, scale, qcfg)
            out = out.reshape(b, s, h, vdim)
        else:
            # absorbed attention: q_nope -> lora space via w_uk (weight_view:
            # the up-projections may arrive packed int8+scales in serving)
            w_uk = Q.weight_view(params["w_uk"], dt).reshape(lora, h, nope)
            q_lora = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)          # (B,1,H,lora)
            s_nope = jnp.einsum("bqhl,btl->bhqt", q_lora, ckv_all.astype(dt))
            s_rope = jnp.einsum("bqhr,btr->bhqt", q_rope, kr_all.astype(dt))
            scores = (s_nope + s_rope).astype(jnp.float32) * scale
            if pv.ndim:
                where = (jnp.arange(t)[None, :] <= pv[:, None])[:, None, None, :]
            else:
                where = (jnp.arange(t) <= pos)[None, None, None, :]
            probs = Q.qsoftmax(scores, qcfg, axis=-1, where=where)
            ctx = jnp.einsum("bhqt,btl->bqhl", probs.astype(dt), ckv_all.astype(dt))
            w_uv = Q.weight_view(params["w_uv"], dt).reshape(lora, h, vdim)
            out = jnp.einsum("bqhl,lhv->bqhv", ctx, w_uv)
    else:
        if cache is not None:
            new_cache = {"ckv": ckv.astype(cache["ckv"].dtype),
                         "krope": k_rope.astype(cache["krope"].dtype)}
            # serving prefill attends the STORED latent (same invariant as
            # the GQA branch): every later reader — decode, incremental
            # chunk prefill — sees the cache dtype, and prefill computing
            # k/v from a higher-precision latent would break their bitwise
            # agreement whenever compute_dtype != the cache dtype
            ckv = new_cache["ckv"].astype(dt)
            k_rope = new_cache["krope"].astype(dt)
        k_nope = Q.qlinear(params["w_uk"], ckv, qcfg).reshape(b, s, h, nope)
        v = Q.qlinear(params["w_uv"], ckv, qcfg).reshape(b, s, h, vdim)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, h, rope_d))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1).reshape(b, s, h, 1, nope + rope_d)
        if s <= FULL_ATTN_MAX:
            out = _full_attention(qq, k, v, positions, jnp.arange(s), True, None, scale, qcfg)
        else:
            out = _chunked_attention(qq, k, v, positions, jnp.arange(s), True, None, scale, qcfg)
        out = out.reshape(b, s, h, vdim)

    out = out.reshape(b, s, h * vdim).astype(dt)
    return Q.qlinear(params["wo"], out, qcfg), new_cache
