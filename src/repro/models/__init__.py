"""Model zoo: decoder (dense/GQA/MLA/MoE/VLM), mamba2 (SSD), griffin
(RG-LRU), whisper (enc-dec) — all quant-aware through repro.quant."""
from repro.models.common import (  # noqa: F401
    ArchConfig, MoEConfig, MLAConfig, SSMConfig, GriffinConfig, EncoderConfig,
)
from repro.models import model  # noqa: F401
