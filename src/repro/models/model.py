"""Family dispatch: one uniform API over all architectures.

  init(cfg, key)                          -> params
  loss_fn(params, cfg, batch, qcfg)       -> (loss, metrics)
  forward(params, cfg, tokens, qcfg, ...) -> (logits, cache|None, aux)
  init_cache(cfg, batch, max_len)         -> cache
  prefill(params, cfg, tokens, qcfg, ...) -> (last logits, cache)
  decode_step(params, cfg, cache, tok, qcfg) -> (logits, cache)

Cache contract: for the decoder family, cache["pos"] is a PER-SLOT position
vector (batch,) int32 — rows may decode at different sequence lengths in one
jitted step (ragged continuous batching). The mamba2/griffin/whisper shims
are sequence-synchronous (scalar pos) and explicitly reject ragged vectors.

A decoder cache carrying "block_table" (n_slots, max_pages) int32 is PAGED
(runtime/paged_kv.py): per-layer stores are page pools (n_pages, page, ...)
shared by all slots and decode_step scatters/gathers through the table;
init_paged_cache builds one. Other families reject the paged layout.
"""
from __future__ import annotations

from repro.models import common as C
from repro.models import griffin as G
from repro.models import mamba2 as M2
from repro.models import transformer as T
from repro.models import whisper as W

_FAMILIES = {
    "decoder": T,
    "mamba2": M2,
    "griffin": G,
    "whisper": W,
}


def family_module(cfg: C.ArchConfig):
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} for arch {cfg.name}") from None


def init(cfg, key):
    return family_module(cfg).init(cfg, key)


def loss_fn(params, cfg, batch, qcfg, remat=True):
    return family_module(cfg).loss_fn(params, cfg, batch, qcfg, remat=remat)


def init_cache(cfg, b, max_len):
    return family_module(cfg).init_cache(cfg, b, max_len)


def init_paged_cache(cfg, n_slots, max_len, *, n_pages, page=None):
    """Paged decoder cache (page pools + block table); see runtime/paged_kv."""
    from repro.runtime import paged_kv as PK
    kw = {} if page is None else {"page": page}
    return PK.init_paged_cache(cfg, n_slots, max_len, n_pages=n_pages, **kw)


def prefill(params, cfg, tokens, qcfg, max_len=None, **extras):
    return family_module(cfg).prefill(params, cfg, tokens, qcfg,
                                      max_len=max_len, **extras)


def decode_step(params, cfg, cache, tokens, qcfg, paged_attn="unfused"):
    mod = family_module(cfg)
    if paged_attn == "unfused":
        return mod.decode_step(params, cfg, cache, tokens, qcfg)
    if cfg.family != "decoder":
        raise ValueError(
            f"paged_attn={paged_attn!r} requires the decoder family (paged "
            f"KV); {cfg.family!r} has no paged cache")
    return mod.decode_step(params, cfg, cache, tokens, qcfg, paged_attn)
