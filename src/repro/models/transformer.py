"""Decoder-family models: dense GQA, MoE, MLA, VLM-stub — one implementation.

Layers are scan-stacked (params carry a leading n_layers dim) so the HLO stays
small for 80-layer configs; per-layer heterogeneity (local/global windows,
per-layer rope theta) rides in as scanned arrays, not separate code paths.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import common as C
from repro.models import ffn as F
from repro.models.partitioning import constrain
from repro.quant import linear as Q

BIG_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: C.ArchConfig, dense_ff: int | None = None) -> dict:
    ks = jax.random.split(key, 4)
    attn = A.mla_init(ks[0], cfg) if cfg.mla else A.gqa_init(ks[0], cfg)
    if cfg.moe and dense_ff is None:
        ff = F.moe_init(ks[1], cfg)
    else:
        ff = F.mlp_init(ks[1], cfg, dense_ff)
    p = {
        "attn_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": attn,
        "ffn_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "ffn": ff,
    }
    if cfg.post_norm:
        p["attn_post_norm"] = C.rmsnorm_init(cfg.d_model, cfg.param_dtype)
        p["ffn_post_norm"] = C.rmsnorm_init(cfg.d_model, cfg.param_dtype)
    return p


def init(cfg: C.ArchConfig, key) -> dict:
    k_embed, k_layers, k_dense, k_head = jax.random.split(key, 4)
    n_dense = cfg.moe.first_dense if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense
    params = {
        "embed": {"w": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02
                        ).astype(cfg.param_dtype)},
        "layers": C.stacked_init(lambda k: _layer_init(k, cfg), k_layers, n_scan),
        "final_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if n_dense:
        dks = jax.random.split(k_dense, n_dense)
        params["dense_layers"] = [
            _layer_init(dks[i], cfg, dense_ff=cfg.moe.d_ff_dense) for i in range(n_dense)]
    if not cfg.tie_embeddings:
        params["lm_head"] = C.dense_init(k_head, cfg.d_model, cfg.vocab,
                                         False, cfg.param_dtype)
    return params


def layer_windows(cfg: C.ArchConfig) -> jnp.ndarray:
    """Per-scanned-layer attention window (BIG_WINDOW = global)."""
    n_dense = cfg.moe.first_dense if cfg.moe else 0
    ws = [BIG_WINDOW if cfg.layer_is_global(i + n_dense) else cfg.sliding_window
          for i in range(cfg.n_layers - n_dense)]
    return jnp.asarray(ws, jnp.int32)


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _layer_apply(lp, h, cfg, qcfg, *, positions, window, cache=None, pos=None,
                 dense_ff=False, block_table=None, paged_attn="unfused"):
    h = constrain(h, "batch", "seq", None)   # pin ZeRO-3 batch sharding
    attn_in = C.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
    if cfg.mla:
        a_out, new_cache = A.mla_apply(lp["attn"], attn_in, cfg, qcfg,
                                       positions=positions, cache=cache, pos=pos,
                                       block_table=block_table,
                                       paged_attn=paged_attn)
    else:
        a_out, new_cache = A.gqa_apply(lp["attn"], attn_in, cfg, qcfg,
                                       positions=positions, causal=True,
                                       window=window, cache=cache, pos=pos,
                                       block_table=block_table,
                                       paged_attn=paged_attn)
    if cfg.post_norm:
        a_out = C.rmsnorm(lp["attn_post_norm"], a_out, cfg.norm_eps)
    h = h + a_out
    ffn_in = C.rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
    aux = jnp.asarray(0.0, jnp.float32)
    if cfg.moe and not dense_ff:
        f_out = F.moe_apply(lp["ffn"], ffn_in, cfg, qcfg, dropless=pos is not None)
        aux = F.moe_aux_loss(lp["ffn"], ffn_in, cfg)
    else:
        f_out = F.mlp_apply(lp["ffn"], ffn_in, cfg, qcfg)
    if cfg.post_norm:
        f_out = C.rmsnorm(lp["ffn_post_norm"], f_out, cfg.norm_eps)
    out = constrain(h + f_out, "batch", "seq", None)
    return out, new_cache, aux


# ---------------------------------------------------------------------------
# forward (train / prefill) and decode
# ---------------------------------------------------------------------------

def _embed(params, cfg, tokens, vis_embed=None):
    h = params["embed"]["w"][tokens].astype(cfg.compute_dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(jnp.sqrt(cfg.d_model), h.dtype)
    if vis_embed is not None:
        h = jnp.concatenate([vis_embed.astype(h.dtype), h], axis=1)
    return h


def _unembed(params, cfg, h):
    if cfg.tie_embeddings:
        return h @ params["embed"]["w"].T.astype(h.dtype)
    return Q.qlinear(params["lm_head"], h, Q.FP)  # lm_head kept fp (std PTQ)


def forward(params, cfg: C.ArchConfig, tokens, qcfg: Q.QuantConfig,
            vis_embed=None, remat: bool = False, cache=None):
    """tokens: (B,S) -> logits (B, S(+vis), V). If cache is given (prefill),
    per-layer caches are filled and returned."""
    h = _embed(params, cfg, tokens, vis_embed)
    b, s, _ = h.shape
    positions = jnp.arange(s)
    windows = layer_windows(cfg)

    n_dense = cfg.moe.first_dense if cfg.moe else 0
    dense_caches = []
    aux_total = jnp.asarray(0.0, jnp.float32)
    for i in range(n_dense):
        lc = None if cache is None else jax.tree.map(lambda x: x[i], cache["dense"])
        h, nc, _ = _layer_apply(params["dense_layers"][i], h, cfg, qcfg,
                                positions=positions, window=None, cache=lc,
                                dense_ff=True)
        dense_caches.append(nc)

    def body(carry, xs):
        h, aux = carry
        lp, window = xs
        w = jnp.where(window >= BIG_WINDOW, s + 1, window)
        h, nc, a = _layer_apply(lp, h, cfg, qcfg, positions=positions, window=w,
                                cache=None if cache is None else _cache_proto(cfg, b, s),
                                pos=None)
        return (h, aux + a), nc

    scan_body = jax.checkpoint(body) if remat else body
    (h, aux_total), layer_caches = jax.lax.scan(
        scan_body, (h, aux_total), (params["layers"], windows))
    h = C.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _unembed(params, cfg, h)

    new_cache = None
    if cache is not None:
        new_cache = {"layers": layer_caches, "pos": jnp.full((b,), s, jnp.int32)}
        if n_dense:
            new_cache["dense"] = jax.tree.map(lambda *xs: jnp.stack(xs), *dense_caches)
    return logits, new_cache, aux_total


def loss_fn(params, cfg: C.ArchConfig, batch: dict, qcfg: Q.QuantConfig,
            remat: bool = True):
    tokens, labels = batch["tokens"], batch["labels"]
    logits, _, aux = forward(params, cfg, tokens, qcfg,
                             vis_embed=batch.get("vis_embed"), remat=remat)
    if cfg.vis_len and batch.get("vis_embed") is not None:
        logits = logits[:, batch["vis_embed"].shape[1]:]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    metrics = {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}
    if cfg.moe:
        n_moe = cfg.n_layers - cfg.moe.first_dense
        loss = loss + 0.01 * aux / jnp.maximum(n_moe, 1)
        metrics["aux_loss"] = aux / jnp.maximum(n_moe, 1)
    return loss, metrics


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _cache_proto(cfg: C.ArchConfig, b: int, t: int):
    """Zero per-layer cache with capacity t (dtype bf16). The leading two
    dims are (batch, time) for the dense layout and (n_pages, page) for the
    paged layout (runtime/paged_kv.py) — same proto either way."""
    if cfg.mla:
        m = cfg.mla
        return {"ckv": jnp.zeros((b, t, m.kv_lora_rank), jnp.bfloat16),
                "krope": jnp.zeros((b, t, m.qk_rope_dim), jnp.bfloat16)}
    return {"k": jnp.zeros((b, t, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
            "v": jnp.zeros((b, t, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)}


cache_proto = _cache_proto   # public alias (paged_kv builds page pools from it)


def init_cache(cfg: C.ArchConfig, b: int, max_len: int):
    """Decoder cache contract: cache["pos"] is a PER-SLOT position vector
    (b,) int32 — batch rows may sit at different sequence lengths (ragged
    continuous batching). Legacy scalar `pos` is still accepted by
    decode_step and broadcast."""
    n_dense = cfg.moe.first_dense if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense
    stack = lambda proto, n: jax.tree.map(
        lambda x: jnp.zeros((n,) + x.shape, x.dtype), proto)
    cache = {"layers": stack(_cache_proto(cfg, b, max_len), n_scan),
             "pos": jnp.zeros((b,), jnp.int32)}
    if n_dense:
        cache["dense"] = stack(_cache_proto(cfg, b, max_len), n_dense)
    return cache


def prefill(params, cfg: C.ArchConfig, tokens, qcfg: Q.QuantConfig,
            max_len: int | None = None, vis_embed=None):
    """Run the prompt, return (last-position logits, filled cache).

    NOTE: prefill writes k/v for the prompt length s; the cache is then
    right-padded to max_len for decoding."""
    b, s = tokens.shape
    logits, cache, _ = forward(params, cfg, tokens, qcfg, vis_embed=vis_embed,
                               cache=init_cache(cfg, b, s))
    if max_len and max_len > s + (vis_embed.shape[1] if vis_embed is not None else 0):
        total = s + (vis_embed.shape[1] if vis_embed is not None else 0)
        pad = max_len - total
        def grow(x):
            if x.ndim >= 3 and x.shape[2] == total:  # (L,B,T,...)
                widths = [(0, 0)] * x.ndim
                widths[2] = (0, pad)
                return jnp.pad(x, widths)
            return x
        cache = {k: (jax.tree.map(grow, v) if k != "pos" else v) for k, v in cache.items()}
    return logits[:, -1], cache


def _step(params, cfg: C.ArchConfig, cache, tokens, qcfg: Q.QuantConfig,
          paged_attn: str = "unfused"):
    """Shared body of decode_step (S=1) and chunk_prefill (S=chunk): run
    tokens (B,S) against the cache at per-slot offsets cache["pos"], writing
    the S new K/V rows and attending at each row's own position. Returns
    (logits (B,S,V), new cache with pos advanced by S). paged_attn="fused"
    routes packed paged attention through the Pallas kernel (GQA layers
    only; MLA ignores it — see attention.mla_apply)."""
    h = _embed(params, cfg, tokens)
    b, s = tokens.shape
    pos = jnp.asarray(cache["pos"], jnp.int32)
    # query rows pos+i: (B,S) for ragged per-slot vectors, (S,) for the
    # scalar dense fast path (s=1 reproduces the old decode shapes exactly)
    positions = pos[:, None] + jnp.arange(s) if pos.ndim else pos + jnp.arange(s)
    windows = layer_windows(cfg)
    block_table = cache.get("block_table")
    if block_table is not None:
        if not pos.ndim:
            raise NotImplementedError("paged caches require per-slot pos (B,)")
        page = jax.tree.leaves(cache["layers"])[0].shape[2]
        t = block_table.shape[1] * page        # gathered per-slot KV extent
    else:
        t = jax.tree.leaves(cache["layers"])[0].shape[2]

    n_dense = cfg.moe.first_dense if cfg.moe else 0
    new_dense = []
    for i in range(n_dense):
        lc = jax.tree.map(lambda x: x[i], cache["dense"])
        h, nc, _ = _layer_apply(params["dense_layers"][i], h, cfg, qcfg,
                                positions=positions, window=None, cache=lc,
                                pos=pos, dense_ff=True, block_table=block_table,
                                paged_attn=paged_attn)
        new_dense.append(nc)

    def body(h, xs):
        lp, lc, window = xs
        w = jnp.where(window >= BIG_WINDOW, t + 1, window)
        h, nc, _ = _layer_apply(lp, h, cfg, qcfg, positions=positions, window=w,
                                cache=lc, pos=pos, block_table=block_table,
                                paged_attn=paged_attn)
        return h, nc

    h, new_layer_caches = jax.lax.scan(body, h, (params["layers"], cache["layers"], windows))
    h = C.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _unembed(params, cfg, h)
    new_cache = dict(cache)
    new_cache["layers"] = new_layer_caches
    new_cache["pos"] = pos + s
    if n_dense:
        new_cache["dense"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_dense)
    return logits, new_cache


def decode_step(params, cfg: C.ArchConfig, cache, tokens, qcfg: Q.QuantConfig,
                paged_attn: str = "unfused"):
    """One token step. tokens: (B,1). Returns (logits (B,V), new cache).

    cache["pos"] is the per-slot position vector (B,) — slots may sit at
    DIFFERENT sequence lengths (ragged continuous batching): each row RoPEs,
    writes K/V, and masks attention at its own position, so one jitted call
    serves the whole batch. A scalar pos keeps the dense fast path (shared
    rope row, contiguous dynamic_update_slice instead of a scatter).

    A cache carrying "block_table" (B, max_pages) is PAGED (see
    runtime/paged_kv.py): per-layer stores are page pools (L, n_pages,
    page, ...) shared by all slots, and attention scatters/gathers through
    the block table instead of indexing a per-slot slab."""
    logits, new_cache = _step(params, cfg, cache, tokens, qcfg, paged_attn)
    return logits[:, 0], new_cache


def chunk_prefill(params, cfg: C.ArchConfig, cache, tokens, qcfg: Q.QuantConfig,
                  paged_attn: str = "unfused"):
    """Incremental chunked prefill: one multi-token step over a PAGED cache.

    tokens (B,S) are S consecutive prompt tokens per slot starting at
    cache["pos"]; their K/V rows scatter straight into the slot's pages
    through the block table (no dense staging cache), and each query attends
    to the already-resident paged KV — including pages mapped in by the
    prefix cache — plus the chunk's own earlier rows, via the same
    gather/mask path decode uses. Returns (logits (B,S,V), new cache with
    pos advanced by S); the caller reads next-token logits at its last REAL
    row (tail chunks are padded to the fixed chunk width, so every prompt
    compiles to ONE shape; pad rows land past the prompt where the position
    mask hides them until decode overwrites them).

    BATCHED MULTI-SLOT contract (runtime/model_runner.py): the B rows may
    belong to DIFFERENT requests at different offsets — cache["pos"] is the
    per-row chunk offset and cache["block_table"] carries each row's own
    table row. Per layer the scatter of ALL rows lands before the gather,
    so a row may read rows another batch row wrote in the same call (the
    lockstep prefix-sharing schedule relies on this); an idle row carries a
    sentinel table row (writes dropped, gathered garbage position-masked)
    and its logits are discarded by the caller."""
    if "block_table" not in cache:
        raise NotImplementedError(
            "chunk_prefill targets paged caches (block_table); dense-layout "
            "prefill uses forward() staging")
    return _step(params, cfg, cache, tokens, qcfg, paged_attn)
