"""Whisper-style encoder-decoder (audio backbone; conv frontend STUBBED —
``frames`` inputs are precomputed frame embeddings (B, n_frames, d)).

Encoder: bidirectional self-attention. Decoder: causal self-attention +
cross-attention over encoder output, learned positional embeddings (no rope,
as in the original). Small (4+4 layers), so layers are unrolled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import common as C
from repro.models import ffn as F
from repro.models.partitioning import constrain
from repro.quant import linear as Q

MAX_DEC_POS = 1 << 20   # learned dec positions are bucketed mod this table


def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"attn_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "attn": A.gqa_init(k1, cfg),
            "ffn_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "ffn": F.mlp_init(k2, cfg)}


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"self_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "self_attn": A.gqa_init(k1, cfg),
            "cross_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "cross_attn": A.gqa_init(k2, cfg),
            "ffn_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "ffn": F.mlp_init(k3, cfg)}


def init(cfg: C.ArchConfig, key) -> dict:
    e = cfg.encoder
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], e.n_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    k_dpos = jax.random.split(ks[5], 1)[0]
    return {
        "embed": {"w": (jax.random.normal(ks[2], (cfg.vocab, cfg.d_model)) * 0.02
                        ).astype(cfg.param_dtype)},
        "enc_pos": {"w": (jax.random.normal(ks[3], (e.n_frames, cfg.d_model)) * 0.01
                          ).astype(cfg.param_dtype)},
        "dec_pos": {"w": (jax.random.normal(k_dpos, (getattr(e, "max_dec_pos", 32768),
                                                     cfg.d_model)) * 0.01
                          ).astype(cfg.param_dtype)},
        "enc_layers": [_enc_layer_init(k, cfg) for k in enc_keys],
        "enc_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "dec_layers": [_dec_layer_init(k, cfg) for k in dec_keys],
        "dec_norm": C.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "lm_head": C.dense_init(ks[4], cfg.d_model, cfg.vocab, False, cfg.param_dtype),
    }


def encode(params, cfg, frames, qcfg):
    """frames: (B, F, d) stub embeddings -> encoder states (B, F, d)."""
    f = frames.shape[1]
    h = frames.astype(cfg.compute_dtype) + params["enc_pos"]["w"][:f].astype(cfg.compute_dtype)
    positions = jnp.arange(f)
    for lp in params["enc_layers"]:
        x = C.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        out, _ = A.gqa_apply(lp["attn"], x, cfg, qcfg, positions=None,
                             causal=False, window=None)
        h = h + out
        h = h + F.mlp_apply(lp["ffn"], C.rmsnorm(lp["ffn_norm"], h, cfg.norm_eps), cfg, qcfg)
    return C.rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def _dec_layer(lp, h, cfg, qcfg, positions, enc_h, enc_pos, cache=None, pos=None):
    h = constrain(h, "batch", "seq", None)
    x = C.rmsnorm(lp["self_norm"], h, cfg.norm_eps)
    out, nc = A.gqa_apply(lp["self_attn"], x, cfg, qcfg, positions=None,
                          causal=True, window=None, cache=cache, pos=pos)
    h = h + out
    x = C.rmsnorm(lp["cross_norm"], h, cfg.norm_eps)
    # cross-attn: kv from encoder states (projected fresh; cheap at 1500 frames)
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    b, f, _ = enc_h.shape
    ck = Q.qlinear(lp["cross_attn"]["wk"], enc_h, qcfg).reshape(b, f, kh, hd)
    cv = Q.qlinear(lp["cross_attn"]["wv"], enc_h, qcfg).reshape(b, f, kh, hd)
    out, _ = A.gqa_apply(lp["cross_attn"], x, cfg, qcfg, positions=None,
                         causal=False, window=None,
                         kv_override=(ck, cv, enc_pos))
    h = h + out
    h = h + F.mlp_apply(lp["ffn"], C.rmsnorm(lp["ffn_norm"], h, cfg.norm_eps), cfg, qcfg)
    return h, nc


def forward(params, cfg: C.ArchConfig, tokens, qcfg, frames=None, remat=False,
            cache=None):
    """Teacher-forced decoder over `tokens` with encoder over `frames`."""
    b, s = tokens.shape
    enc_h = encode(params, cfg, frames, qcfg)
    enc_pos = jnp.arange(enc_h.shape[1])
    h = params["embed"]["w"][tokens].astype(cfg.compute_dtype)
    h = h + params["dec_pos"]["w"][:s].astype(h.dtype)   # learned positions
    positions = jnp.arange(s)
    caches = []
    for i, lp in enumerate(params["dec_layers"]):
        lc = None
        if cache is not None:
            lc = {"k": jnp.zeros((b, s, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
                  "v": jnp.zeros((b, s, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)}
        h, nc = _dec_layer(lp, h, cfg, qcfg, positions, enc_h, enc_pos,
                           cache=lc)
        caches.append(nc)
    h = C.rmsnorm(params["dec_norm"], h, cfg.norm_eps)
    logits = Q.qlinear(params["lm_head"], h, Q.FP)
    new_cache = None
    if cache is not None:
        new_cache = {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *caches),
                     "enc_h": enc_h, "pos": jnp.asarray(s, jnp.int32)}
    return logits, new_cache, jnp.asarray(0.0, jnp.float32)


def loss_fn(params, cfg, batch, qcfg, remat=True):
    logits, _, _ = forward(params, cfg, batch["tokens"], qcfg,
                           frames=batch["frames"], remat=remat)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


def init_cache(cfg: C.ArchConfig, b: int, max_len: int):
    L = cfg.n_layers
    return {
        "layers": {"k": jnp.zeros((L, b, max_len, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
                   "v": jnp.zeros((L, b, max_len, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)},
        "enc_h": jnp.zeros((b, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16),
        "pos": jnp.asarray(0, jnp.int32),
    }


def prefill(params, cfg, tokens, qcfg, max_len=None, frames=None, vis_embed=None):
    b, s = tokens.shape
    logits, cache, _ = forward(params, cfg, tokens, qcfg, frames=frames, cache={})
    max_len = max_len or s
    full = init_cache(cfg, b, max_len)
    full["layers"] = jax.tree.map(
        lambda dstv, srcv: jax.lax.dynamic_update_slice_in_dim(dstv, srcv, 0, axis=2),
        full["layers"], cache["layers"])
    full["enc_h"] = cache["enc_h"].astype(jnp.bfloat16)
    full["pos"] = jnp.asarray(s, jnp.int32)
    return logits[:, -1], full


def decode_step(params, cfg, cache, tokens, qcfg):
    if jnp.ndim(cache["pos"]):
        raise NotImplementedError(
            "whisper decode uses a learned position-table lookup shared by "
            "the batch; ragged per-slot positions (pos vector) are "
            "unsupported — pad the batch to a common length instead")
    pos = cache["pos"]
    b = tokens.shape[0]
    enc_h = cache["enc_h"].astype(cfg.compute_dtype)
    enc_pos = jnp.arange(enc_h.shape[1])
    h = params["embed"]["w"][tokens].astype(cfg.compute_dtype)
    h = h + jax.lax.dynamic_slice_in_dim(params["dec_pos"]["w"], pos, 1, 0
                                         ).astype(h.dtype)[None]
    new_layers = []
    for i, lp in enumerate(params["dec_layers"]):
        lc = jax.tree.map(lambda x: x[i], cache["layers"])
        h, nc = _dec_layer(lp, h, cfg, qcfg, None, enc_h, enc_pos, cache=lc, pos=pos)
        new_layers.append(nc)
    h = C.rmsnorm(params["dec_norm"], h, cfg.norm_eps)
    logits = Q.qlinear(params["lm_head"], h, Q.FP)[:, 0]
    new_cache = dict(cache)
    new_cache["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
    new_cache["pos"] = pos + 1
    return logits, new_cache
