from repro.runtime.resilient import (  # noqa: F401
    FailureInjector, StragglerMonitor, resilient_train_loop,
)
from repro.runtime.batcher import ContinuousBatcher, Request  # noqa: F401
from repro.runtime.paged_kv import (  # noqa: F401
    PAGE_SIZE, PagedKVAllocator, init_paged_cache, pages_for,
)
