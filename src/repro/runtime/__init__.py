"""Serving/runtime engine.

The continuous-batching engine is three collaborating layers behind the
``ContinuousBatcher`` façade; each owns a disjoint slice of state and the
seams between them are ordinary method calls, so every layer is testable
on its own:

  * ``Scheduler`` (runtime/scheduler.py) — POLICY. Owns the wait queue
    (rank-sorted: priority desc, arrival asc), the slot seating map, the
    per-slot written-row mirror, and the preemption policy (admission-
    blocked and append-exhausted eviction, recompute-on-readmit
    bookkeeping). Pure host Python: never touches jax, params, or device
    arrays — unit-testable with a mock runner.

  * ``KVCacheManager`` (runtime/kv_manager.py) — MEMORY. Owns the physical
    page pool: free list, refcounts, per-slot page lists, reservations
    (strict worst-case or relaxed prompt-only), the RADIX PREFIX TREE over
    page-granular token chunks, and the LRU that retains retired pages
    until the pool actually reclaims them. Host Python; the façade mirrors
    its decisions into the device block table.

  * ``ModelRunner`` (runtime/model_runner.py) — EXECUTION. Owns params,
    the QuantConfig, and every compiled shape: the one-jitted-decode-per-
    tick step, the dense bucketed-prefill reference ladder, and batched
    multi-slot chunked prefill (one compiled ``(prefill_slots, chunk)``
    call serving several admissions per step). All counters that describe
    compiled work (prefill_traces, chunk_prefill_calls, prefill_steps)
    live here.

``ContinuousBatcher`` (runtime/batcher.py) composes the three, owns the
device cache pytree + block table, and keeps the public ``submit`` /
``step`` / ``run`` / ``kv_stats`` API stable. ``PagedKVAllocator``
(runtime/paged_kv.py) remains the bare bookkeeping base class
KVCacheManager extends.
"""
from repro.runtime.faults import (  # noqa: F401
    ChaosInjector, FailureInjector, InjectedFailure, ReplicaKilled,
    StragglerMonitor,
)
from repro.runtime.resilient import resilient_train_loop  # noqa: F401
from repro.runtime.batcher import ContinuousBatcher, Request  # noqa: F401
from repro.runtime.kv_manager import KVCacheManager  # noqa: F401
from repro.runtime.model_runner import ModelRunner  # noqa: F401
from repro.runtime.paged_kv import (  # noqa: F401
    PAGE_SIZE, PagedKVAllocator, PoolExhausted, init_paged_cache, pages_for,
)
from repro.runtime.scheduler import Scheduler  # noqa: F401
