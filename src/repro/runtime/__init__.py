from repro.runtime.resilient import (  # noqa: F401
    FailureInjector, StragglerMonitor, resilient_train_loop,
)
from repro.runtime.batcher import ContinuousBatcher, Request  # noqa: F401
