"""Scheduler: admission order, seating, and preemption policy of the engine.

One of the three engine layers (Scheduler / KVCacheManager / ModelRunner —
see runtime/__init__.py for the contract). The scheduler is PURE HOST
PYTHON: it never touches jax, params, or the device cache, so its whole
policy surface is unit-testable with a mock runner (tests/test_engine.py).
It owns:

  * the WAIT QUEUE, kept sorted by rank — ``(priority desc, arrival asc)``;
    equal-priority traffic is FIFO, and a preempted request re-enters at
    the position its original arrival earns, not at the back;
  * SEATING: ``slot_req`` maps decode slots to running requests and
    ``rows`` mirrors each slot's written-KV height (the facade syncs the
    device ``cache["pos"]`` from it);
  * the PREEMPTION POLICY (``preempt=True``; requires a relaxed-capacity
    ``KVCacheManager``). Two triggers:
      - ADMISSION-BLOCKED: the queue head outranks a running sequence but
        the pool cannot admit it -> evict a strictly lower-ranked running
        sequence and retry. Because rank falls back to arrival order, plain
        FIFO traffic never admission-preempts (the head arrived last); a
        higher ``Request.priority`` or an earlier-arrived readmission does.
      - APPEND-EXHAUSTED: a decode-time page append finds the pool empty
        (relaxed mode reserves prompt pages only, so the pool may be
        oversubscribed) -> evict a running sequence — possibly the
        appender itself — until the append succeeds.
    VICTIM SELECTION is COST-AWARE (``_pick_victim``): among eligible
    slots, evict the one whose readmission recomputes the fewest KV rows
    (written rows minus rows of pages the radix tree still indexes — those
    survive in the manager's retired LRU and match straight back),
    tie-broken by lowest rank. Pure rank order would throw away a long,
    expensively decoded sequence when an equally-eligible cheap one frees
    the same pages.
    Eviction releases the victim's pages (shared pages survive via
    refcounts; indexed pages stay radix-reachable in the manager's retired
    LRU) and requeues the request with its generated tokens: on readmission
    the victim's KV is RECOMPUTED by chunk-prefilling
    ``prompt + out_tokens[:-1]`` (minus whatever prefix the radix tree
    still holds), and decoding resumes from its last generated token —
    greedy decode makes the result bit-identical to an uninterrupted run.

The facade (``runtime.batcher.ContinuousBatcher``) drives the tick:
``schedule()`` -> run the planned admissions through the ModelRunner ->
``seat``/``retire`` -> ``secure_appends()`` -> decode -> ``note_decoded``.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.runtime import paged_kv as PK


def kv_rows_needed(p_len: int, max_new: int) -> int:
    """Worst-case KV rows a request ever occupies. The first generated
    token comes from prefill and the LAST generated token is never written
    back, so a request needs prompt + max_new - 1 rows (max_new >= 1 — a
    request that generates nothing is not a request). The single source of
    the footprint rule: submit-time validation (batcher) and admission-time
    reservation (schedule) both use it."""
    if max_new < 1:
        raise ValueError(f"max_new must be >= 1, got {max_new}")
    return p_len + max_new - 1


@dataclasses.dataclass
class Admission:
    """One planned admission: the facade prefills `tokens[start_row:]` into
    `page_ids` and then seats (or, for `resume`, re-seats) the request."""
    slot: int
    req: object
    tokens: list                # rows resident after prefill (prompt/resume)
    page_ids: list
    n_shared: int               # leading pages served by the radix index
    start_row: int              # first row chunk-prefill must compute
    resume: bool                # readmission of a preempted request


class Scheduler:
    """Admission + preemption policy over a KVCacheManager (or None for the
    dense slab layout, where the per-slot slab is the only capacity)."""

    def __init__(self, kv, n_slots: int, *, page_size: int = PK.PAGE_SIZE,
                 preempt: bool = False, prefix_cache: bool = True):
        assert not (preempt and kv is None), "preemption requires paged KV"
        assert kv is None or not (preempt and kv.strict_reserve), \
            "preemption requires a relaxed-capacity KVCacheManager"
        self.kv = kv
        self.n_slots, self.page = n_slots, page_size
        self.preempt_enabled = preempt
        self.prefix_cache = prefix_cache and kv is not None
        self.queue: collections.deque = collections.deque()
        self.slot_req: list = [None] * n_slots
        self.rows: list[int] = [0] * n_slots    # written KV rows per slot
        # bumped on every seat/retire/preempt: the overlapped loop snapshots
        # it at decode dispatch to detect ANY occupancy change at collect —
        # request identity alone is fooled by a preempt-then-readmit-into-
        # the-same-slot round (same req, same slot, pages moved)
        self.slot_epoch: list[int] = [0] * n_slots
        self.preemptions = 0
        self.recomputed_tokens = 0              # rows re-prefilled on readmit
        self._arrivals = 0

    # -- queue -------------------------------------------------------------

    def submit(self, req, tokens):
        """Enqueue `req` with its host-side prompt tokens."""
        req._tokens = np.asarray(tokens, np.int32)
        req._arrival = self._arrivals
        req._resume = None
        req._toklist = None
        self._arrivals += 1
        self._enqueue(req)

    def _host_tokens(self, req) -> list:
        """The request's resident-token sequence (resume tokens once
        preempted) as a python int list, cached on the request — a
        pool-blocked head is re-matched against the radix tree every tick
        and must not re-convert its whole prompt each time (``submit`` and
        ``preempt`` invalidate the cache)."""
        lst = req._toklist
        if lst is None:
            src = req._resume if req._resume is not None else req._tokens
            lst = req._toklist = [int(t) for t in src]
        return lst

    def _rank(self, req):
        """Higher tuple = more important. Ties break to earlier arrival."""
        return (getattr(req, "priority", 0), -req._arrival)

    def _enqueue(self, req):
        """Insert keeping the queue sorted best-rank-first (stable FIFO for
        equal priorities; readmissions resume their arrival position)."""
        i = len(self.queue)
        while i > 0 and self._rank(self.queue[i - 1]) < self._rank(req):
            i -= 1
        self.queue.insert(i, req)

    def _live(self) -> list[int]:
        return [s for s, r in enumerate(self.slot_req) if r is not None]

    def _recompute_cost(self, slot: int) -> int:
        """KV rows a preemption of `slot` would force back through prefill:
        the slot's written rows minus the rows of pages the prefix index
        (radix tree) still holds — those survive eviction in the manager's
        retired LRU and will be matched straight back on readmission."""
        if self.kv is None:
            return self.rows[slot]
        saved = sum(1 for pid in self.kv.pages[slot]
                    if self.kv.page_indexed(pid))
        return max(0, self.rows[slot] - saved * self.page)

    def _pick_victim(self, below=None) -> int | None:
        """Cost-aware victim selection: among live slots (optionally only
        those ranked strictly below `below`), evict the CHEAPEST to redo —
        fewest non-radix-indexed KV rows — tie-broken by lowest rank. Pure
        rank selection would happily throw away a long, expensively
        decoded sequence when a short one (or one whose pages are all
        still radix-cached) frees the same pages for free."""
        cand = self._live()
        if below is not None:
            cand = [s for s in cand
                    if self._rank(self.slot_req[s]) < below]
        if not cand:
            return None
        return min(cand, key=lambda s: (self._recompute_cost(s),
                                        self._rank(self.slot_req[s])))

    # -- admission ---------------------------------------------------------

    def schedule(self) -> tuple[list[Admission], list[int]]:
        """Plan this tick's admissions (head-of-line order). Returns
        (admissions, evicted slots). Paged: pages are allocated and radix-
        registered here; the facade runs the prefill and seats. Under
        ``preempt=True`` an admission-blocked head may evict strictly
        lower-ranked running sequences."""
        admissions: list[Admission] = []
        evicted: list[int] = []
        while self.queue:
            slot = next((s for s, r in enumerate(self.slot_req)
                         if r is None), None)
            if slot is None:
                break
            req = self.queue[0]
            if self.kv is None:                 # dense slab: always admits
                self.queue.popleft()
                self.slot_req[slot] = req
                admissions.append(Admission(slot, req, req._tokens,
                                            [], 0, 0, False))
                continue
            toks = self._host_tokens(req)
            n = len(toks)
            total = kv_rows_needed(len(req._tokens), req.max_new)
            shared = self.kv.match_tokens(toks, (n - 1) // self.page) \
                if self.prefix_cache else []
            if not self.kv.can_admit_rows(n, total, shared):
                victim = self._pick_victim(below=self._rank(req)) \
                    if self.preempt_enabled else None
                if victim is not None:
                    evicted.append(self.preempt(victim))
                    continue                    # retry the head (re-match)
                if self.preempt_enabled and not self._live() and \
                        self.kv.used_count == 0:
                    # nothing is live and the whole pool is reclaimable,
                    # yet the head still does not fit: it can NEVER admit
                    # (a preempted sequence that outgrew the pool mid-life)
                    raise RuntimeError(
                        f"request {req.rid} can never be admitted: its "
                        f"resident footprint needs more than the whole "
                        f"page pool ({self.kv.n_pages} pages) and no eos "
                        f"arrived before it outgrew it")
                break                           # head-of-line: wait
            self.queue.popleft()
            pids = self.kv.admit(slot, n, total, shared=shared)
            if self.prefix_cache:
                self.kv.register_tokens(toks, pids)
            self.slot_req[slot] = req
            self.rows[slot] = 0                 # set by seat() after prefill
            start = len(shared) * self.page
            resume = req._resume is not None
            if resume:
                self.recomputed_tokens += max(0, n - start)
            admissions.append(Admission(slot, req, toks, pids,
                                        len(shared), start, resume))
        return admissions, evicted

    def seat(self, slot: int, n_rows: int):
        """Prefill done: record the slot's resident KV height."""
        self.rows[slot] = n_rows
        self.slot_epoch[slot] += 1

    def retire(self, slot: int):
        """Release a finished (or prefill-retired) slot."""
        if self.kv is not None:
            self.kv.release(slot)
        self.slot_req[slot] = None
        self.rows[slot] = 0
        self.slot_epoch[slot] += 1

    def note_decoded(self, slots=None):
        """One decode tick happened: every live slot wrote one KV row.
        The overlapped engine loop passes `slots` explicitly — only the
        slots whose occupant is UNCHANGED since the decode was dispatched
        wrote a row they keep (a slot preempted or re-seated while the
        decode was in flight discards that write), so crediting `_live()`
        would corrupt the row mirror of the new occupant."""
        for s in (self._live() if slots is None else slots):
            self.rows[s] += 1

    def outstanding(self) -> int:
        """Queued + running requests — the drain condition of the async
        front door (zero means a graceful shutdown may stop the loop)."""
        return len(self.queue) + len(self._live())

    def cancel(self, rid: int) -> int | None:
        """Abort request `rid` wherever it is: drop it from the wait queue
        (no slot held — returns -1) or retire its slot (pages released,
        epoch bumped so an in-flight decode's token for the slot is
        discarded at collect — returns the slot for block-table clearing).
        Returns None when the request is not queued or running (already
        finished, or never submitted). The front door uses this for
        per-request timeouts and poisoned-request isolation."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                return -1
        for s, req in enumerate(self.slot_req):
            if req is not None and req.rid == rid:
                self.retire(s)
                return s
        return None

    # -- preemption --------------------------------------------------------

    def preempt(self, slot: int) -> int:
        """Evict `slot`: requeue its request with the generated tokens so a
        readmission recomputes ``prompt + out_tokens[:-1]`` (the last token
        is not yet in KV — it becomes the resumed ``cur_tok``)."""
        req = self.slot_req[slot]
        assert req is not None and req.out_tokens, "preempting an empty slot"
        resume = np.concatenate(
            [req._tokens, np.asarray(req.out_tokens[:-1], np.int32)])
        assert len(resume) == self.rows[slot], (len(resume), self.rows[slot])
        req._resume = resume
        req._toklist = None            # the resident-token cache is stale
        if self.kv is not None:
            self.kv.preempt_release(slot, resume)
        self.slot_req[slot] = None
        self.rows[slot] = 0
        self.slot_epoch[slot] += 1
        self.preemptions += 1
        self._enqueue(req)
        return slot

    def secure_appends(self) -> tuple[list[tuple], list[int]]:
        """Pre-decode page appends for every live slot, best rank first.
        Strict mode never fails (reservation invariant). Relaxed mode
        preempts the lowest-ranked live sequence on PoolExhausted — the
        appender itself when it ranks lowest — until the append lands.
        Returns (grown [(slot, page_index, page_id)], evicted slots)."""
        grown: list[tuple] = []
        evicted: list[int] = []
        order = sorted(self._live(),
                       key=lambda s: self._rank(self.slot_req[s]),
                       reverse=True)
        for slot in order:
            if self.slot_req[slot] is None:
                continue                        # evicted by an earlier append
            while True:
                try:
                    res = self.kv.ensure_row(slot, self.rows[slot])
                    if res is not None:
                        grown.append((slot, *res))
                    break
                except PK.PoolExhausted:
                    if not self.preempt_enabled:
                        raise
                    victim = self._pick_victim()
                    if victim == slot and len(self._live()) == 1:
                        raise RuntimeError(
                            f"request {self.slot_req[slot].rid} cannot make "
                            f"progress: it holds the whole page pool "
                            f"({self.kv.n_pages} pages) and still needs to "
                            f"append — its worst case does not fit the pool "
                            f"and no eos arrived") from None
                    evicted.append(self.preempt(victim))
                    if victim == slot:
                        break                   # the appender was the victim
        return grown, evicted
