"""Continuous-batching serving engine façade (Scheduler/KVCacheManager/
ModelRunner composition).

``ContinuousBatcher`` keeps the public serving API (``submit`` / ``step`` /
``run`` / ``kv_stats``) but is now a thin façade over three collaborating
layers with explicit seams (see runtime/__init__.py for the contract):

  * ``runtime.scheduler.Scheduler`` — wait queue, admission order, seating,
    and the PREEMPTION policy (pure host Python);
  * ``runtime.kv_manager.KVCacheManager`` — page pool + refcounts + the
    RADIX PREFIX TREE over page-granular token chunks, with LRU retention
    of retired pages (host Python; the device block table mirrors it here);
  * ``runtime.model_runner.ModelRunner`` — params, jit caches, compiled
    shapes: the one-per-tick decode, the dense bucket ladder, and BATCHED
    MULTI-SLOT chunked prefill (one compiled ``(prefill_slots, chunk)``
    call prefills a chunk for several admissions per step).

Serving contract (unchanged from the monolith, tested in
tests/test_ragged_decode.py, tests/test_paged_kv.py,
tests/test_prefix_cache.py):
  * one shared KV cache whose cache["pos"] is a PER-SLOT position vector
    (B,) int32; step() issues exactly ONE jitted decode call per tick;
  * "paged" layout (default): pages of 32 KV rows = one BBFP quantisation
    block, allocated on admission, appended on page-boundary crossings,
    released on retirement; "dense" keeps the (B, max_len) slab reference;
  * prefix cache: a request sharing a page-aligned token prefix with any
    indexed sequence — resident OR recently retired (the radix tree's LRU
    keeps zero-refcount pages until the pool actually reclaims them) —
    maps those pages copy-on-write and skips their prefill;
  * kv_storage="packed" pages hold int8 codes + shared exponents;
    "packed4" halves them again (two nibble codes per byte, ~4.25 bits/elt)
    and requires paged_attn="fused" — only the Pallas kernel
    (kernels/paged_attention.py) decodes nibble pages, in VMEM.

Preemption (``preempt=True``, paged only): admission reserves only the
prompt's pages, so the pool may be OVERSUBSCRIBED — more concurrent
sequences than worst-case capacity, and requests whose worst case exceeds
the pool are accepted at submit (they complete whenever eos lands early
enough). When a decode-time append (or a higher-priority admission) finds
the pool exhausted, the lowest-priority running sequence is evicted: its
private pages free (shared pages survive via refcounts, indexed pages stay
radix-reachable), and the request requeues with its generated tokens for
recompute-on-readmit — chunk prefill of ``prompt + out_tokens[:-1]``
(minus surviving prefix pages), then decode resumes from its last token.
Greedy decode makes the interrupted run token-identical to an
uninterrupted one. ``kv_stats`` reports ``preemptions``,
``recomputed_tokens``, and the radix index size.

OVERLAPPED ENGINE LOOP (``step_overlapped`` / ``run_overlapped``; paged
layout). The synchronous ``step`` serialises host and device: it blocks on
the decode's tokens before planning the next tick. The overlapped tick
reorders the same work into three phases so the host runs tick N+1's
policy while tick N's decode is still executing on the device:

  A. PLAN (host, device busy): one scheduling round — queue policy, radix
     matching, page allocation, block-table writes — and the batched
     chunk-prefill DISPATCH. Everything here is host Python or an
     asynchronous jax dispatch; the prefill's final-row logits stay
     device futures.
  B. STREAM EDGE: the only blocking point (``ModelRunner.decode_collect``
     → ``jax.block_until_ready``). The in-flight decode's tokens are
     applied — but ONLY to slots whose occupant is unchanged since
     dispatch: a slot preempted (and possibly re-seated) during phase A
     discards its in-flight token, which the victim re-generates after
     readmission, keeping greedy output token-identical to the
     synchronous path. Then the pending admissions' finals resolve
     (seat, or retire-at-prefill).
  C. APPEND + DISPATCH: page appends for the grown rows, then the next
     decode is dispatched and the tick returns without waiting for it.

``overlapped_ticks`` counts ticks where phase A actually had policy work
(a non-empty wait queue or planned admissions) while a decode was in
flight — evidence the overlap happened; ``host_idle_ticks`` counts ticks
where the host had nothing to do and went straight to the stream edge.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime import paged_kv as PK
from repro.runtime.kv_manager import KVCacheManager
from repro.runtime.model_runner import ModelRunner
from repro.runtime.scheduler import Scheduler, kv_rows_needed  # noqa: F401
# kv_rows_needed is re-exported here (its historical home); the formula
# itself lives next to the admission reservation in runtime/scheduler.py
# so submit-time validation and schedule-time accounting cannot diverge.


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (P,) int32
    max_new: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    priority: int = 0              # higher = may preempt lower (preempt mode)


class ContinuousBatcher:
    def __init__(self, cfg, params, qcfg: Q.QuantConfig, *,
                 n_slots: int = 4, max_len: int = 128, eos_id: int | None = None,
                 kv_layout: str = "paged", page_size: int = PK.PAGE_SIZE,
                 n_pages: int | None = None, min_prefill_bucket: int = 16,
                 kv_storage: str = "fp", prefix_cache: bool = True,
                 prefill_chunk: int = 32, prefill_slots: int | None = None,
                 preempt: bool = False, runner: ModelRunner | None = None,
                 mesh=None, paged_attn: str = "unfused"):
        assert cfg.family == "decoder", "batcher targets the decoder family"
        assert kv_layout in ("paged", "dense"), kv_layout
        assert kv_storage in ("fp", "packed", "packed4"), kv_storage
        assert paged_attn in ("fused", "unfused"), paged_attn
        self.cfg, self.params, self.qcfg = cfg, params, qcfg
        self.mesh = mesh
        self.n_slots, self.max_len, self.eos = n_slots, max_len, eos_id
        self.paged = kv_layout == "paged"
        self.kv_storage = kv_storage
        self.paged_attn = paged_attn
        self.page_size = page_size
        self.prefix_cache = prefix_cache and self.paged
        self.prefill_chunk = max(1, prefill_chunk)
        self.preempt = preempt
        if preempt and not self.paged:
            raise ValueError("preempt=True requires kv_layout='paged' "
                             "(the dense slab has no pages to evict)")
        if kv_storage in ("packed", "packed4"):
            # packed pages store int8 codes in qcfg.kv_fmt — the storage
            # format IS the cache-quantisation format, so it must be set
            # (and the pool layout must be paged: pages = quant blocks)
            if not self.paged:
                raise ValueError(
                    f"kv_storage={kv_storage!r} requires kv_layout='paged'")
            if qcfg.kv_cache == "none":
                raise ValueError(
                    f"kv_storage={kv_storage!r} needs qcfg.kv_cache set (e.g. "
                    "'BBFP(6,3)') — it is the page storage format")
        if kv_storage == "packed4" and paged_attn != "fused":
            # the jnp fallback would gather + nibble-dequantise the whole
            # paged view to bf16 EVERY tick — the format exists to cut
            # decode bandwidth, and only the fused kernel decodes it in VMEM
            raise ValueError(
                "kv_storage='packed4' requires paged_attn='fused' (the "
                "unfused jnp path would dequantise nibble pages per tick)")
        if paged_attn == "fused":
            if not self.paged or kv_storage == "fp":
                raise ValueError(
                    "paged_attn='fused' requires kv_layout='paged' with "
                    "kv_storage='packed' or 'packed4' (the kernel decodes "
                    "int8 BBFP pages; fp pools have nothing to fuse)")
        # which jnp-vs-fused path the model will ACTUALLY run: MLA has no
        # fused kernel (absorbed-form latent attention doesn't fit its
        # shape), so fused requests downgrade — mla_apply warns once and
        # kv_stats surfaces the effective path
        self.paged_attn_effective = \
            "unfused" if (paged_attn == "fused" and cfg.mla is not None) \
            else paged_attn
        # the mesh the engine will really run on (a shared runner's mesh
        # wins — adoption below rebinds self.mesh to it) must be known
        # BEFORE the pool is sized: fused + TP page-shards the pool, so
        # n_pages has to divide the "model" axis
        eff_mesh = runner.mesh if runner is not None else mesh
        tp_size = 1
        if eff_mesh is not None:
            tp_size = dict(zip(eff_mesh.axis_names,
                               eff_mesh.devices.shape)).get("model", 1)
        # KV sharding mode for this engine: the fused kernel runs per
        # page-pool shard inside a shard_map (flash-decoding sequence
        # parallelism — no kv_heads divisibility requirement); the jnp
        # path head-shards the pools as before
        self._kv_shard_axis = "pages" \
            if self.paged_attn_effective == "fused" else "heads"
        if self.paged:
            self.max_pages = PK.pages_for(max_len, page_size)
            # default budget = dense-equivalent capacity (no overcommit);
            # pass a smaller n_pages to overcommit the pool
            self.n_pages = n_pages if n_pages is not None \
                else n_slots * self.max_pages
            if self._kv_shard_axis == "pages" and tp_size > 1:
                # page-dim sharding splits the pool over the "model" axis:
                # round the pool UP to a shard multiple (extra pages only
                # add capacity; the sentinel moves with n_pages)
                self.n_pages += (-self.n_pages) % tp_size
            self.kv = KVCacheManager(self.n_pages, page_size, n_slots,
                                     strict_reserve=not preempt,
                                     retain=self.prefix_cache)
            self.cache = PK.init_paged_cache(
                cfg, n_slots, max_len, n_pages=self.n_pages, page=page_size,
                storage=kv_storage,
                kv_fmt=qcfg.kv_fmt if kv_storage != "fp" else None)
        else:
            self.kv = None
            self.cache = M.init_cache(cfg, n_slots, max_len)  # cache["pos"]: (B,)
        self.sched = Scheduler(self.kv, n_slots, page_size=page_size,
                               preempt=preempt, prefix_cache=self.prefix_cache)
        if runner is not None:
            # a shared runner (one jit-cache across façades — bench sweeps,
            # server restarts, fleet replicas) must execute the same model
            # and formats; a mesh-holding runner already sharded the params,
            # so the facade adopts its mesh + committed param tree
            assert runner.cfg is cfg and \
                (runner.params is params or runner._params_src is params), \
                "shared ModelRunner must hold this façade's cfg/params"
            assert runner.qcfg == qcfg, "shared ModelRunner qcfg mismatch"
            assert runner.paged_attn == paged_attn, \
                "shared ModelRunner paged_attn mismatch (the fused/unfused " \
                "choice is baked into its jitted closures)"
            self.runner = runner
            self.prefill_chunk = runner.prefill_chunk
            self.mesh = mesh = runner.mesh
            self.params = runner.params
        else:
            self.runner = ModelRunner(cfg, params, qcfg,
                                      prefill_chunk=self.prefill_chunk,
                                      prefill_slots=prefill_slots or n_slots,
                                      min_prefill_bucket=min_prefill_bucket,
                                      mesh=mesh, paged_attn=paged_attn)
            self.params = self.runner.params
        if self.paged and mesh is not None:
            # commit the pools to the mesh — head-sharded for the jnp path,
            # page-sharded for fused; block table / pos stay replicated, so
            # the Scheduler and KVCacheManager bookkeeping above (pure
            # host Python over page ids) is untouched by tensor parallelism
            self.cache = PK.shard_paged_cache(self.cache, mesh,
                                              shard_axis=self._kv_shard_axis)
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self._decode = self.runner.make_decode()
        self.decode_calls = 0          # jitted decode invocations (1 per tick)
        self.prefix_hit_pages = 0      # prompt pages served from the index
        self.prefix_miss_pages = 0     # prompt pages computed by prefill
        self.finished: list[Request] = []
        # overlapped-loop state: the in-flight decode (logits future + the
        # slot->request snapshot at dispatch) and the proof counters
        self._inflight: tuple | None = None
        self.overlapped_ticks = 0      # ticks with host policy work while a
        #                                decode was in flight (real overlap)
        self.host_idle_ticks = 0       # ticks that went straight to the edge

    # -- façade surface (delegation) ---------------------------------------

    @property
    def alloc(self):
        """The page manager (None for the dense layout); kept under the
        monolith's name so allocator-level introspection keeps working."""
        return self.kv

    @property
    def queue(self):
        return self.sched.queue

    @property
    def slot_req(self):
        return self.sched.slot_req

    @property
    def prefill_traces(self) -> int:
        return self.runner.prefill_traces

    @prefill_traces.setter
    def prefill_traces(self, v: int):
        self.runner.prefill_traces = v

    @property
    def chunk_prefill_calls(self) -> int:
        return self.runner.chunk_prefill_calls

    @chunk_prefill_calls.setter
    def chunk_prefill_calls(self, v: int):
        self.runner.chunk_prefill_calls = v

    @property
    def prefill_steps(self) -> int:
        return self.runner.prefill_steps

    @property
    def preemptions(self) -> int:
        return self.sched.preemptions

    @property
    def recomputed_tokens(self) -> int:
        return self.sched.recomputed_tokens

    @property
    def pos(self) -> list[int]:
        """Host copy of the per-slot KV position vector."""
        return [int(p) for p in jax.device_get(self.cache["pos"])]

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt pages served from the prefix cache."""
        total = self.prefix_hit_pages + self.prefix_miss_pages
        return self.prefix_hit_pages / total if total else 0.0

    def _bucket(self, p_len: int) -> int:
        return self.runner.bucket(p_len)

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        # a ragged decode write past max_len is silently dropped (scatter
        # mode="drop"), so a request that cannot fit would diverge from
        # sequential decoding with no error — reject it up front instead.
        need = kv_rows_needed(req.prompt.shape[0], req.max_new)
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid} needs up to {need} KV rows (prompt "
                f"{req.prompt.shape[0]} + max_new {req.max_new} - 1) but the "
                f"shared cache capacity is max_len={self.max_len}")
        if self.paged:
            # strict mode charges the worst case; preempt mode admits
            # optimistically (only an early eos can complete a request whose
            # worst case exceeds the pool — the no-progress guard fails it
            # loudly otherwise) but still needs the prompt plus the first
            # decode write to fit. Either way a request over its budget
            # would spin unserved at the head of the queue — reject it at
            # submit instead.
            floor = min(need, req.prompt.shape[0] + 1) if self.preempt else need
            if PK.pages_for(floor, self.page_size) > self.n_pages:
                raise ValueError(
                    f"request {req.rid} needs {PK.pages_for(floor, self.page_size)} "
                    f"pages (KV rows {floor} / page {self.page_size}) but the "
                    f"page pool budget is n_pages={self.n_pages}")
        self.sched.submit(req, np.asarray(jax.device_get(req.prompt), np.int32))

    def cancel(self, rid: int) -> bool:
        """Abort a queued or running request: remove it from the wait
        queue, or retire its slot — releasing its pages (shared pages
        survive via refcounts, radix-indexed pages stay cached) and
        clearing its block-table row. Safe between ticks only (the async
        front door calls it from the engine loop while no tick is in
        flight); an in-flight decode's token for the cancelled slot is
        discarded via the slot-epoch check, exactly like preemption.
        Returns True when the request was found and cancelled."""
        where = self.sched.cancel(rid)
        if where is None:
            return False
        if where >= 0:
            self._clear_slots([where])
        return True

    def _clear_slots(self, slots: list[int]):
        """Reset evicted/retired slots' block-table rows to the sentinel
        BEFORE the next compiled call: their pages may be reallocated this
        very tick, and a stale row would scatter into the new owner."""
        if self.paged and slots:
            bt = self.cache["block_table"].at[
                jnp.asarray(slots, jnp.int32)].set(self.kv.sentinel)
            self.cache = {**self.cache, "block_table": bt}

    def _finish_admission(self, slot: int, req: Request, tok: int) -> bool:
        """Common admission tail: record the prefill token; retire budget-
        met / EOS-at-prefill requests without occupying the slot, otherwise
        seat the request. Returns True when the slot was taken."""
        req.out_tokens.append(tok)
        if len(req.out_tokens) >= req.max_new or \
                (self.eos is not None and tok == self.eos):
            req.done = True
            self.finished.append(req)
            self.sched.retire(slot)
            return False
        self._seat(slot, req, tok, req.prompt.shape[0])
        return True

    def _seat(self, slot: int, req: Request, tok: int, n_rows: int):
        self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
        self.cache = {**self.cache,
                      "pos": self.cache["pos"].at[slot].set(n_rows)}
        self.sched.seat(slot, n_rows)

    def _dispatch_admissions(self, admissions) -> list:
        """DISPATCH half of one scheduling round's paged admissions: write
        the block-table rows, launch ONE batched multi-slot chunked prefill
        over all of them (asynchronous — the final-row logits stay device
        futures), and seat resume admissions immediately (their next token
        is already known host-side). Returns the pending non-resume
        admissions as ``[(adm, final_logits_future)]`` for
        ``_resolve_admissions`` to finish at the stream edge."""
        bt = self.cache["block_table"]
        for adm in admissions:
            bt = bt.at[adm.slot, :len(adm.page_ids)].set(
                jnp.asarray(adm.page_ids, jnp.int32))
        self.cache = {**self.cache, "block_table": bt}
        # a job depends on the lockstep schedule only when its shared
        # prefix pages are WRITTEN by another admission of this round;
        # prefixes already resident (earlier ticks, radix LRU) start at 0
        fresh = set()
        for adm in admissions:
            fresh.update(adm.page_ids[adm.n_shared:])
        jobs = [(adm.slot, adm.tokens, adm.start_row,
                 bool(set(adm.page_ids[:adm.n_shared]) & fresh))
                for adm in admissions]
        self.cache, finals = self.runner.batched_chunk_prefill(
            self.cache, jobs, self.kv.sentinel)
        pending = []
        for adm in admissions:
            self.prefix_hit_pages += adm.n_shared
            self.prefix_miss_pages += \
                PK.pages_for(len(adm.tokens), self.page_size) - adm.n_shared
            if adm.resume:
                # readmission of a preempted request: its KV (minus radix
                # hits) was just recomputed; decoding resumes from the last
                # generated token — no new token is taken from the prefill
                self._seat(adm.slot, adm.req, int(adm.req.out_tokens[-1]),
                           len(adm.tokens))
            else:
                pending.append((adm, finals[adm.slot]))
        return pending

    def _resolve_admissions(self, pending) -> list:
        """COLLECT half of an admission round: read each pending prefill's
        final-row logits (blocking) and seat — or retire-at-prefill — the
        request. Returns streaming events ``(req, [token], done)``."""
        cleared, events = [], []
        for adm, fin in pending:
            tok = int(jnp.argmax(fin))
            if not self._finish_admission(adm.slot, adm.req, tok):
                cleared.append(adm.slot)   # retired at prefill: drop pages
            events.append((adm.req, [tok], adm.req.done))
        self._clear_slots(cleared)
        return events

    def _admit_paged(self, admissions):
        """Synchronous admission (the ``step()`` path): dispatch + resolve
        back-to-back, exactly the monolith's semantics."""
        self._resolve_admissions(self._dispatch_admissions(admissions))

    def _admit_dense(self, adm):
        """Dense-layout admission: bucketed staging prefill + slab splice."""
        logits, staged = self.runner.dense_prefill(adm.req.prompt)
        tok = int(jnp.argmax(logits))
        p_len = adm.req.prompt.shape[0]
        if self._finish_admission(adm.slot, adm.req, tok):
            self._splice_dense(adm.slot, staged, p_len)

    def _splice_dense(self, slot: int, staged_cache, p_len: int):
        """Copy a prefilled request's K/V rows into rows [0, p_len) of
        `slot` in the shared dense cache (leading dims: layers..., batch,
        time, ...); the slot's pos entry is then set to p_len by _seat."""
        def one(dst, src):
            if dst.ndim < 3 or dst.shape[1] != self.n_slots:
                return dst
            # src: (L, 1|b, >=p_len, ...) -> write rows [0, p_len) of `slot`
            upd = jax.lax.dynamic_slice_in_dim(src, 0, 1, axis=1)
            upd = jax.lax.dynamic_slice_in_dim(upd, 0, min(p_len, dst.shape[2]), axis=2)
            return jax.lax.dynamic_update_slice(
                dst, upd.astype(dst.dtype),
                (0, slot, 0) + (0,) * (dst.ndim - 3))
        new_cache = {**self.cache,
                     "layers": jax.tree.map(one, self.cache["layers"],
                                            staged_cache["layers"])}
        if "dense" in self.cache:   # MoE archs with leading dense layers
            new_cache["dense"] = jax.tree.map(one, self.cache["dense"],
                                              staged_cache["dense"])
        self.cache = new_cache

    def _admit(self):
        """Run scheduling rounds until no further admission is possible
        (a round's prefill may retire requests at admission and free their
        slots for the next round — the monolith's while-loop semantics)."""
        while True:
            admissions, evicted = self.sched.schedule()
            self._clear_slots(evicted)
            if not admissions:
                break
            if self.paged:
                self._admit_paged(admissions)
            else:
                for adm in admissions:
                    self._admit_dense(adm)

    # -- the decode tick ----------------------------------------------------

    def step(self):
        """One batched decode tick: admit (batched prefill, possibly
        preempting), secure page appends (possibly preempting), ONE jitted
        decode over all slots (each at its own position), retire finished
        requests."""
        self._admit()
        if all(r is None for r in self.sched.slot_req):
            return False
        if self.paged:
            # append a page to any slot whose write this tick crosses a page
            # boundary (strict mode: infallible, covered by the admission
            # reservation; preempt mode: may evict the lowest-priority
            # sequence); one batched table write for all appends this tick
            grown, evicted = self.sched.secure_appends()
            self._clear_slots(evicted)
            if grown:
                rows, cols, vals = (jnp.asarray(v, jnp.int32)
                                    for v in zip(*grown))
                bt = self.cache["block_table"].at[rows, cols].set(vals)
                self.cache = {**self.cache, "block_table": bt}
            if all(r is None for r in self.sched.slot_req):
                return bool(self.queue)
        logits, new_cache = self._decode(self.params, self.cache, self.cur_tok)
        self.decode_calls += 1
        toks = jax.device_get(jnp.argmax(logits, axis=-1))      # (B,) host
        retired = []
        for s, req in enumerate(self.sched.slot_req):
            if req is None:
                continue
            tok = int(toks[s])
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new or \
                    (self.eos is not None and tok == self.eos):
                req.done = True
                self.finished.append(req)
                retired.append(s)
        # single vectorized state update: live slots take their new token and
        # advanced position; idle/finished/preempted slots pin back to pos 0
        self.sched.note_decoded()
        for s in retired:
            # drop the retired slot's page references (shared pages survive
            # until their last reader retires; indexed pages stay cached in
            # the radix LRU until the pool reclaims them)
            self.sched.retire(s)
        live = jnp.asarray([r is not None for r in self.sched.slot_req])
        self.cur_tok = jnp.where(live[:, None],
                                 jnp.asarray(toks, jnp.int32)[:, None],
                                 self.cur_tok)
        self.cache = {**new_cache,
                      "pos": jnp.where(live, new_cache["pos"], 0)}
        self._clear_slots(retired)
        return True

    def run(self, max_ticks: int = 1000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.sched.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished, ticks

    # -- the overlapped tick (host/device pipelining) -----------------------

    def _collect_inflight(self) -> list:
        """Stream edge for the in-flight decode: block on its logits, then
        apply each token ONLY to slots whose occupant is unchanged since
        dispatch (phase-A preemption may have evicted — and re-seated — a
        slot mid-flight; the victim's token is discarded and re-generated
        after readmission). Mirrors the synchronous ``step()`` tail:
        append, note_decoded, retire, cur_tok update, slot clearing.
        Returns streaming events ``(req, [token], done)``."""
        if self._inflight is None:
            return []
        logits, snapshot, epochs = self._inflight
        self._inflight = None
        toks = self.runner.decode_collect(logits)   # the ONLY blocking point
        consistent = [s for s, r in enumerate(snapshot)
                      if r is not None and self.sched.slot_req[s] is r
                      and self.sched.slot_epoch[s] == epochs[s]]
        events, retired = [], []
        for s in consistent:
            req = snapshot[s]
            tok = int(toks[s])
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new or \
                    (self.eos is not None and tok == self.eos):
                req.done = True
                self.finished.append(req)
                retired.append(s)
            events.append((req, [tok], req.done))
        self.sched.note_decoded(consistent)
        for s in retired:
            self.sched.retire(s)
        keep = [s for s in consistent if s not in retired]
        if keep:
            idx = jnp.asarray(keep, jnp.int32)
            self.cur_tok = self.cur_tok.at[idx, 0].set(
                jnp.asarray(toks, jnp.int32)[idx])
        self._clear_slots(retired)
        return events

    def step_overlapped(self) -> tuple[bool, list]:
        """One OVERLAPPED engine tick (paged layout): plan tick N+1's
        admissions on the host while tick N's decode runs on the device,
        block only at the stream edge, then dispatch the next decode and
        return WITHOUT waiting for it. Returns ``(progress, events)``
        where events are ``(req, [token], done)`` tuples for the streaming
        front door. Token-identical to the synchronous ``step()`` path
        under greedy decode (verified by tests and the bench gate)."""
        assert self.paged, "the overlapped loop requires kv_layout='paged'"
        # -- phase A: host policy work (device may be busy) ----------------
        had_queue = bool(self.sched.queue)
        admissions, evicted = self.sched.schedule()
        self._clear_slots(evicted)
        pending = self._dispatch_admissions(admissions) if admissions else []
        if self._inflight is not None:
            if had_queue or admissions:
                self.overlapped_ticks += 1
            else:
                self.host_idle_ticks += 1
        # -- phase B: stream edge ------------------------------------------
        events = self._collect_inflight()
        events.extend(self._resolve_admissions(pending))
        # -- phase C: appends + dispatch the next decode -------------------
        if all(r is None for r in self.sched.slot_req):
            return bool(self.queue), events
        grown, evicted = self.sched.secure_appends()
        self._clear_slots(evicted)
        if grown:
            rows, cols, vals = (jnp.asarray(v, jnp.int32)
                                for v in zip(*grown))
            bt = self.cache["block_table"].at[rows, cols].set(vals)
            self.cache = {**self.cache, "block_table": bt}
        if all(r is None for r in self.sched.slot_req):
            return bool(self.queue), events
        # idle/finished/preempted slots pin back to pos 0 BEFORE dispatch
        # (the synchronous path pins after collect; here the cache must be
        # consistent when the decode launches)
        live = jnp.asarray([r is not None for r in self.sched.slot_req])
        self.cache = {**self.cache,
                      "pos": jnp.where(live, self.cache["pos"], 0)}
        logits, new_cache = self._decode(self.params, self.cache, self.cur_tok)
        self.decode_calls += 1
        self.cache = new_cache          # device futures; host keeps planning
        self._inflight = (logits, list(self.sched.slot_req),
                          list(self.sched.slot_epoch))
        return True, events

    def run_overlapped(self, max_ticks: int = 1000):
        """Drain the queue through the overlapped loop (the synchronous
        ``run``'s parity twin; the async server drives ``step_overlapped``
        itself so it can interleave arrivals)."""
        ticks = 0
        while ticks < max_ticks and \
                (self.queue or self._inflight is not None
                 or any(r is not None for r in self.sched.slot_req)):
            self.step_overlapped()
            ticks += 1
        return self.finished, ticks

    # -- warm restart --------------------------------------------------------

    def snapshot_kv(self, ckpt_dir: str, step: int = 0) -> int:
        """Persist the radix prefix cache (index + page contents) through
        the checkpoint store. Returns the number of snapshotted pages."""
        assert self.paged, "snapshot_kv requires kv_layout='paged'"
        return self.kv.snapshot_kv(self.cache, ckpt_dir, step)

    def restore_kv(self, ckpt_dir: str, step: int | None = None) -> int:
        """Warm-start this engine's prefix cache from a ``snapshot_kv``
        directory: restored chains land in the retired LRU with their
        saved page contents, so the first admission round already gets
        prefix hits. Returns the number of restored pages (0 when the
        directory holds no snapshot)."""
        assert self.paged, "restore_kv requires kv_layout='paged'"
        self.cache, n = self.kv.restore_kv(self.cache, ckpt_dir, step)
        if n and self.mesh is not None:
            # the restore scatters GLOBAL page contents host-side; re-commit
            # the pools to this engine's mesh layout (snapshots are
            # shard-count agnostic — a tp=2 snapshot restores onto tp=1 or
            # tp=4 engines, head- or page-sharded alike)
            self.cache = PK.shard_paged_cache(self.cache, self.mesh,
                                              shard_axis=self._kv_shard_axis)
        return n

    # -- introspection ------------------------------------------------------

    def kv_stats(self) -> dict:
        """Serving-path memory counters for the bench trajectory. Under
        prefix sharing, LOGICAL bytes are what the slots collectively
        reference (shared pages counted once per reader) while PHYSICAL
        bytes are what the pool actually stores for LIVE sequences — their
        ratio is the dedup win the prefix cache delivers. Retired-but-
        cached pages (the radix LRU) are reported as `pages_cached`."""
        total = PK.kv_bytes(self.cache)
        kv_shards = 1
        if self.mesh is not None:
            kv_shards = dict(zip(self.mesh.axis_names,
                                 self.mesh.devices.shape)).get("model", 1)
        stats = {"kv_layout": "paged" if self.paged else "dense",
                 "kv_storage": self.kv_storage,
                 "paged_attn": self.paged_attn,
                 "paged_attn_effective": self.paged_attn_effective,
                 "kv_shard_axis": self._kv_shard_axis
                 if self.mesh is not None else None,
                 "kv_store_bytes": total,
                 "kv_shards": kv_shards,
                 "kv_store_bytes_per_shard": PK.kv_bytes_shard(self.cache),
                 "kv_bytes_per_slot": total // self.n_slots}
        if self.paged:
            per_page = total // max(self.n_pages, 1)
            physical, logical = self.kv.used_count, self.kv.logical_count
            stats.update(pages_total=self.n_pages,
                         pages_in_use=physical,
                         pages_logical=logical,
                         pages_shared=self.kv.shared_count,
                         pages_cached=self.kv.cached_count,
                         kv_bytes_in_use=per_page * physical,
                         kv_bytes_physical=per_page * physical,
                         kv_bytes_logical=per_page * logical,
                         prefix_hit_rate=self.prefix_hit_rate,
                         radix_pages=self.kv.radix_size,
                         preemptions=self.sched.preemptions,
                         recomputed_tokens=self.sched.recomputed_tokens)
        return stats
