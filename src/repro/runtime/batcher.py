"""Continuous-batching serving scheduler (slot-based, vLLM-style-lite).

A fixed pool of B slots runs a single jitted decode step per tick; requests
are admitted into free slots as others finish (EOS or max_new), so the
decode batch stays full instead of draining to the slowest request —
the thing that actually determines serving throughput at scale.

Ragged-position cache contract (tested in tests/test_ragged_decode.py):
  * one shared KV cache whose cache["pos"] is a PER-SLOT position vector
    (B,) int32 — slots at arbitrary, distinct sequence lengths decode
    together. Each row RoPEs its query, writes its K/V, and masks attention
    at its own position;
  * consequently step() issues exactly ONE jitted decode call per tick, no
    matter how many distinct lengths are active (the old implementation
    looped over position groups, degrading exactly when traffic is ragged);
  * requests that cannot fit (prompt + max_new - 1 > max_len; the LAST
    generated token is never written back) are rejected at submit();
  * idle and just-finished slots keep decoding garbage in the same call —
    their pos is pinned back to 0 and their outputs discarded, so they cost
    one masked row instead of a retrace.

KV layouts (tested in tests/test_paged_kv.py, tests/test_prefix_cache.py):
  * "paged" (default) — the cache is a pool of 32-row pages shared by all
    slots (runtime/paged_kv.py): pages are allocated on ADMISSION (prompt
    pages, plus a worst-case reservation so decode appends can never fail),
    APPENDED one at a time as a slot's decode crosses a page boundary, and
    RELEASED on retirement (refcounted: a page only truly frees when its
    last reader retires). KV memory tracks the pool's actual load instead
    of n_slots * max_len, and a page is always aligned to the BBFP
    32-element quantisation block;
  * "dense" — the original (B, max_len) slab per layer; kept as the
    reference layout and for the bench comparison.

Page-native admission (paged layout):
  * PREFIX CACHE (`prefix_cache=True`): a request whose prompt shares a
    32-token-page-aligned prefix with a resident sequence maps the matching
    pages into its block table (refcount++, copy-on-write: shared pages are
    immutable full prompt pages; the last partial page — and the page
    holding the last prompt token, whose logits must be recomputed — stay
    private) and SKIPS that share of prefill compute and storage entirely.
    Because a page is exactly one BBFP quantisation block, the shared pages
    are bit-identical to what the request would have computed;
  * INCREMENTAL CHUNKED PREFILL: the (post-prefix) prompt remainder runs in
    fixed `prefill_chunk`-token jitted steps (transformer.chunk_prefill)
    whose queries attend to the already-resident paged KV through the block
    table and whose K/V rows scatter straight into the request's pages — no
    max_len-sized dense staging cache, and ONE compiled prefill shape
    regardless of prompt length (tail chunks pad to the chunk width;
    `prefill_traces` counts 1). `chunk_prefill_calls` counts the chunk
    steps actually run, so prefix hits are measurable as skipped chunks.

KV storage (paged only; `kv_storage` parameter):
  * "fp" (default) — pages hold bf16 values;
  * "packed" — pages hold int8 codes + int8 per-32-block shared exponents
    in qcfg.kv_fmt (runtime/paged_kv.packed_proto): 8.25 bits/elt at
    BBFP(6,3) vs 16, and token-for-token identical to the fp pool for GQA
    because cache writes already sit on the format grid.

The dense layout keeps the legacy bucketed prefill: a staging cache whose
length is the prompt rounded up to a power-of-two BUCKET (min
`min_prefill_bucket`), compilations O(log max_len), rows [0, p_len) spliced
into the slot's slab rows.

Works with every decoder-family arch and any QuantConfig (incl. the full
BBAL serving stack). SSM/griffin caches are sequence-synchronous (scalar
pos, no per-slot time index) and explicitly reject ragged position vectors,
so the batcher targets the transformer family (the assigned serving
shapes' family).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime import paged_kv as PK


def kv_rows_needed(p_len: int, max_new: int) -> int:
    """Worst-case KV rows a request ever occupies. The first generated
    token comes from prefill and the LAST generated token is never written
    back, so a request needs prompt + max_new - 1 rows (max_new >= 1 — a
    request that generates nothing is not a request)."""
    if max_new < 1:
        raise ValueError(f"max_new must be >= 1, got {max_new}")
    return p_len + max_new - 1


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (P,) int32
    max_new: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg, params, qcfg: Q.QuantConfig, *,
                 n_slots: int = 4, max_len: int = 128, eos_id: int | None = None,
                 kv_layout: str = "paged", page_size: int = PK.PAGE_SIZE,
                 n_pages: int | None = None, min_prefill_bucket: int = 16,
                 kv_storage: str = "fp", prefix_cache: bool = True,
                 prefill_chunk: int = 32):
        assert cfg.family == "decoder", "batcher targets the decoder family"
        assert kv_layout in ("paged", "dense"), kv_layout
        assert kv_storage in ("fp", "packed"), kv_storage
        self.cfg, self.params, self.qcfg = cfg, params, qcfg
        self.n_slots, self.max_len, self.eos = n_slots, max_len, eos_id
        self.paged = kv_layout == "paged"
        self.kv_storage = kv_storage
        self.page_size = page_size
        self.min_bucket = max(1, min_prefill_bucket)
        self.prefix_cache = prefix_cache and self.paged
        self.prefill_chunk = max(1, prefill_chunk)
        if kv_storage == "packed":
            # packed pages store int8 codes in qcfg.kv_fmt — the storage
            # format IS the cache-quantisation format, so it must be set
            # (and the pool layout must be paged: pages = quant blocks)
            if not self.paged:
                raise ValueError("kv_storage='packed' requires kv_layout='paged'")
            if qcfg.kv_cache == "none":
                raise ValueError(
                    "kv_storage='packed' needs qcfg.kv_cache set (e.g. "
                    "'BBFP(6,3)') — it is the page storage format")
        if self.paged:
            self.max_pages = PK.pages_for(max_len, page_size)
            # default budget = dense-equivalent capacity (no overcommit);
            # pass a smaller n_pages to overcommit the pool
            self.n_pages = n_pages if n_pages is not None \
                else n_slots * self.max_pages
            self.alloc = PK.PagedKVAllocator(self.n_pages, page_size, n_slots)
            self.cache = PK.init_paged_cache(
                cfg, n_slots, max_len, n_pages=self.n_pages, page=page_size,
                storage=kv_storage,
                kv_fmt=qcfg.kv_fmt if kv_storage == "packed" else None)
        else:
            self.alloc = None
            self.cache = M.init_cache(cfg, n_slots, max_len)  # cache["pos"]: (B,)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)
        # the pre-call cache is never touched after a tick: donate it so XLA
        # aliases the new pool onto the old instead of double-buffering the
        # whole KV store every decode (no-op on CPU, real aliasing on TPU)
        self._decode = jax.jit(
            lambda p, c, t: M.decode_step(p, cfg, c, t, qcfg),
            donate_argnums=(1,))
        self.decode_calls = 0          # jitted decode invocations (1 per tick)
        self._prefill_fns: dict[int, object] = {}   # bucket -> jitted prefill
        self._chunk_prefill_fn = None  # the ONE jitted chunk-prefill shape
        self.prefill_traces = 0        # distinct prefill shapes compiled
        self.chunk_prefill_calls = 0   # chunk steps run (hits skip chunks)
        self.prefix_hit_pages = 0      # prompt pages served from the index
        self.prefix_miss_pages = 0     # prompt pages computed by prefill
        self._host_pos = [0] * n_slots  # host mirror of live slots' pos
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []

    @property
    def pos(self) -> list[int]:
        """Host copy of the per-slot KV position vector."""
        return [int(p) for p in jax.device_get(self.cache["pos"])]

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt pages served from the prefix cache."""
        total = self.prefix_hit_pages + self.prefix_miss_pages
        return self.prefix_hit_pages / total if total else 0.0

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        # a ragged decode write past max_len is silently dropped (scatter
        # mode="drop"), so a request that cannot fit would diverge from
        # sequential decoding with no error — reject it up front instead.
        need = kv_rows_needed(req.prompt.shape[0], req.max_new)
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid} needs up to {need} KV rows (prompt "
                f"{req.prompt.shape[0]} + max_new {req.max_new} - 1) but the "
                f"shared cache capacity is max_len={self.max_len}")
        if self.paged and PK.pages_for(need, self.page_size) > self.n_pages:
            # can_admit() would never hold, so the request (and everything
            # FIFO-queued behind it) would spin unserved — reject up front
            raise ValueError(
                f"request {req.rid} needs {PK.pages_for(need, self.page_size)} "
                f"pages (KV rows {need} / page {self.page_size}) but the page "
                f"pool budget is n_pages={self.n_pages}")
        self.queue.append(req)

    def _prefix_keys(self, prompt, n: int) -> list[bytes]:
        """Page-aligned prefix keys for the first `n` pages: key i is the
        sha256 CHAIN digest of page i's token bytes onto key i-1, so each
        key identifies the full prefix through its page in O(1) bytes (an
        identity key would make a p-page chain cost O(p^2) bytes to build
        and store; collisions of chained sha256 are not a practical
        concern). Resolved entirely on the host at admission."""
        toks = np.asarray(jax.device_get(prompt), np.int32).tobytes()
        stride = 4 * self.page_size
        keys, h = [], b""
        for i in range(n):
            h = hashlib.sha256(h + toks[i * stride:(i + 1) * stride]).digest()
            keys.append(h)
        return keys

    def _match_prefix(self, req: Request) -> tuple[list[int], list[bytes]]:
        """(resident shared-prefix page ids, the prompt's full-page keys).
        Sharing is capped at the page BEFORE the one holding the last
        prompt token: only KV is cached, so the last token always reruns
        through chunk prefill to produce the next-token logits. Keys are
        cached on the request — a head-of-queue request re-matched every
        tick under pool pressure hashes its prompt only once."""
        if not self.prefix_cache:
            return [], []
        keys = getattr(req, "_prefix_keys", None)
        if keys is None:
            p_len = int(req.prompt.shape[0])
            keys = req._prefix_keys = self._prefix_keys(
                req.prompt, p_len // self.page_size)
        shareable = (int(req.prompt.shape[0]) - 1) // self.page_size
        return self.alloc.match_prefix(keys[:shareable]), keys

    def _bucket(self, p_len: int) -> int:
        """Dense-layout prompt staging length: next power of two >= p_len
        (floored at min_bucket) — an O(log max_len) shape ladder."""
        return max(self.min_bucket, 1 << max(p_len - 1, 0).bit_length())

    def _prefill(self, prompt: jnp.ndarray):
        """Dense-layout bucketed prefill: pad the prompt to its bucket, run
        one jitted forward per BUCKET (not per length), read logits at row
        p_len-1 (the padded tail is causally invisible to real rows).
        Returns (next-token logits (V,), staged cache of bucket rows)."""
        p_len = prompt.shape[0]
        bkt = self._bucket(p_len)
        fn = self._prefill_fns.get(bkt)
        if fn is None:
            mod = M.family_module(self.cfg)
            cfg, qcfg = self.cfg, self.qcfg

            def run(params, toks):
                logits, cache, _ = mod.forward(
                    params, cfg, toks, qcfg,
                    cache=mod.init_cache(cfg, 1, toks.shape[1]))
                return logits, cache

            fn = jax.jit(run)
            self._prefill_fns[bkt] = fn
            self.prefill_traces += 1
        toks = jnp.pad(prompt.astype(jnp.int32), (0, bkt - p_len))[None, :]
        logits, staged = fn(self.params, toks)
        return logits[0, p_len - 1], staged

    def _chunk_fn(self):
        """The single jitted chunk-prefill step: (params, {layers[,dense],
        block_table row, pos}, (1, prefill_chunk) tokens) -> (logits, new
        KV). ONE shape for every prompt length — compare the dense ladder's
        O(log max_len)."""
        if self._chunk_prefill_fn is None:
            cfg, qcfg = self.cfg, self.qcfg
            mod = M.family_module(cfg)

            def run(params, kv, bt_row, pos0, toks):
                sub = {**kv, "block_table": bt_row, "pos": pos0}
                logits, new_cache = mod.chunk_prefill(params, cfg, sub, toks, qcfg)
                return logits, {k: v for k, v in new_cache.items()
                                if k in ("layers", "dense")}

            # donate the KV pool (arg 1 holds only the pool leaves — the
            # table row and pos pass through undonated): chunk i+1's pool
            # aliases chunk i's instead of double-buffering the store
            self._chunk_prefill_fn = jax.jit(run, donate_argnums=(1,))
            self.prefill_traces += 1
        return self._chunk_prefill_fn

    def _chunked_prefill(self, slot: int, prompt, start: int):
        """Incremental chunked prefill of prompt rows [start, p_len) —
        start > 0 when a shared prefix is already resident — straight into
        `slot`'s pages. Each fixed-width chunk is one jitted multi-token
        step attending to everything already resident via the block table;
        the tail chunk pads to the chunk width (pad rows scatter past
        p_len inside the slot's own reservation, stay position-masked, and
        decode overwrites them). Returns the last REAL row's logits (V,)."""
        chunk = self.prefill_chunk
        p_len = int(prompt.shape[0])
        fn = self._chunk_fn()
        logits = real = None
        for off in range(start, p_len, chunk):
            real = min(chunk, p_len - off)
            toks = jnp.pad(prompt[off:off + real].astype(jnp.int32),
                           (0, chunk - real))[None, :]
            kv = {"layers": self.cache["layers"]}
            if "dense" in self.cache:
                kv["dense"] = self.cache["dense"]
            logits, new_kv = fn(self.params, kv,
                                self.cache["block_table"][slot:slot + 1],
                                jnp.asarray([off], jnp.int32), toks)
            self.cache = {**self.cache, **new_kv}
            self.chunk_prefill_calls += 1
        return logits[0, real - 1]

    def _finish_admission(self, slot: int, req: Request, tok: int) -> bool:
        """Common admission tail: record the prefill token; retire budget-
        met / EOS-at-prefill requests without occupying the slot, otherwise
        seat the request. Returns True when the slot was taken."""
        req.out_tokens.append(tok)
        if len(req.out_tokens) >= req.max_new or \
                (self.eos is not None and tok == self.eos):
            req.done = True
            self.finished.append(req)
            return False
        self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
        p_len = req.prompt.shape[0]
        self.cache = {**self.cache,
                      "pos": self.cache["pos"].at[slot].set(p_len)}
        self._host_pos[slot] = p_len
        self.slot_req[slot] = req
        return True

    def _admit_paged(self, slot: int, req: Request, shared: list[int],
                     keys: list[bytes]) -> bool:
        """Page-native admission: map shared prefix pages + allocate the
        rest, chunk-prefill the remainder straight into them, register the
        now-resident full prompt pages for future sharing."""
        p_len = req.prompt.shape[0]
        need_rows = kv_rows_needed(p_len, req.max_new)
        pids = self.alloc.admit(slot, p_len, need_rows, shared=shared)
        bt = self.cache["block_table"].at[slot, :len(pids)].set(
            jnp.asarray(pids, jnp.int32))
        self.cache = {**self.cache, "block_table": bt}
        logits = self._chunked_prefill(slot, req.prompt,
                                       start=len(shared) * self.page_size)
        self.prefix_hit_pages += len(shared)
        self.prefix_miss_pages += PK.pages_for(p_len, self.page_size) - len(shared)
        tok = int(jnp.argmax(logits))
        if not self._finish_admission(slot, req, tok):
            # budget met / EOS at prefill: drop the transient pages
            self.alloc.release(slot)
            bt = self.cache["block_table"].at[slot].set(self.alloc.sentinel)
            self.cache = {**self.cache, "block_table": bt}
            return False
        if self.prefix_cache:
            self.alloc.register_prefix(keys, pids[:len(keys)])
        return True

    def _admit_dense(self, slot: int, req: Request) -> bool:
        """Dense-layout admission: bucketed staging prefill + slab splice."""
        logits, staged = self._prefill(req.prompt)
        tok = int(jnp.argmax(logits))
        p_len = req.prompt.shape[0]
        seated = self._finish_admission(slot, req, tok)
        if seated:
            self._splice_dense(slot, staged, p_len)
        return seated

    def _splice_dense(self, slot: int, staged_cache, p_len: int):
        """Copy a prefilled request's K/V rows into rows [0, p_len) of
        `slot` in the shared dense cache (leading dims: layers..., batch,
        time, ...); the slot's pos entry is then set to p_len by _admit."""
        def one(dst, src):
            if dst.ndim < 3 or dst.shape[1] != self.n_slots:
                return dst
            # src: (L, 1|b, >=p_len, ...) -> write rows [0, p_len) of `slot`
            upd = jax.lax.dynamic_slice_in_dim(src, 0, 1, axis=1)
            upd = jax.lax.dynamic_slice_in_dim(upd, 0, min(p_len, dst.shape[2]), axis=2)
            return jax.lax.dynamic_update_slice(
                dst, upd.astype(dst.dtype),
                (0, slot, 0) + (0,) * (dst.ndim - 3))
        new_cache = {**self.cache,
                     "layers": jax.tree.map(one, self.cache["layers"],
                                            staged_cache["layers"])}
        if "dense" in self.cache:   # MoE archs with leading dense layers
            new_cache["dense"] = jax.tree.map(one, self.cache["dense"],
                                              staged_cache["dense"])
        self.cache = new_cache

    def _admit(self):
        for slot in range(self.n_slots):
            while self.slot_req[slot] is None and self.queue:
                req = self.queue[0]
                if self.paged:
                    shared, keys = self._match_prefix(req)
                    need = kv_rows_needed(req.prompt.shape[0], req.max_new)
                    if not self.alloc.can_admit(need, n_shared=len(shared)):
                        return   # FIFO: wait for a retirement to free pages
                    self.queue.popleft()
                    self._admit_paged(slot, req, shared, keys)
                else:
                    self.queue.popleft()
                    self._admit_dense(slot, req)

    # -- the decode tick ----------------------------------------------------

    def step(self):
        """One batched decode tick: admit, ONE jitted decode over all slots
        (each at its own position), retire finished requests."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        if self.paged:
            # append a page to any slot whose write this tick crosses a page
            # boundary (infallible: covered by the admission reservation);
            # one batched table write for all appends this tick
            grown = []      # (slot, page_index, page_id)
            for s, req in enumerate(self.slot_req):
                if req is None:
                    continue
                res = self.alloc.ensure_row(s, self._host_pos[s])
                if res is not None:
                    grown.append((s, *res))
            if grown:
                rows, cols, vals = (jnp.asarray(v, jnp.int32)
                                    for v in zip(*grown))
                bt = self.cache["block_table"].at[rows, cols].set(vals)
                self.cache = {**self.cache, "block_table": bt}
        logits, new_cache = self._decode(self.params, self.cache, self.cur_tok)
        self.decode_calls += 1
        toks = jax.device_get(jnp.argmax(logits, axis=-1))      # (B,) host
        retired = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(toks[s])
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new or \
                    (self.eos is not None and tok == self.eos):
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
                retired.append(s)
        # single vectorized state update: live slots take their new token and
        # advanced position; idle/finished slots are pinned back to pos 0
        live = jnp.asarray([r is not None for r in self.slot_req])
        self.cur_tok = jnp.where(live[:, None],
                                 jnp.asarray(toks, jnp.int32)[:, None],
                                 self.cur_tok)
        self.cache = {**new_cache,
                      "pos": jnp.where(live, new_cache["pos"], 0)}
        for s in range(self.n_slots):
            self._host_pos[s] = self._host_pos[s] + 1 \
                if self.slot_req[s] is not None else 0
        if self.paged and retired:
            # drop the retired slots' page references (shared pages survive
            # until their last reader retires) and reset their table rows
            for s in retired:
                self.alloc.release(s)
            bt = self.cache["block_table"].at[
                jnp.asarray(retired, jnp.int32)].set(self.alloc.sentinel)
            self.cache = {**self.cache, "block_table": bt}
        return True

    def run(self, max_ticks: int = 1000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished, ticks

    # -- introspection ------------------------------------------------------

    def kv_stats(self) -> dict:
        """Serving-path memory counters for the bench trajectory. Under
        prefix sharing, LOGICAL bytes are what the slots collectively
        reference (shared pages counted once per reader) while PHYSICAL
        bytes are what the pool actually stores — their ratio is the
        dedup win the prefix cache delivers."""
        total = PK.kv_bytes(self.cache)
        stats = {"kv_layout": "paged" if self.paged else "dense",
                 "kv_storage": self.kv_storage,
                 "kv_store_bytes": total,
                 "kv_bytes_per_slot": total // self.n_slots}
        if self.paged:
            per_page = total // max(self.n_pages, 1)
            physical, logical = self.alloc.used_count, self.alloc.logical_count
            stats.update(pages_total=self.n_pages,
                         pages_in_use=physical,
                         pages_logical=logical,
                         pages_shared=self.alloc.shared_count,
                         kv_bytes_in_use=per_page * physical,
                         kv_bytes_physical=per_page * physical,
                         kv_bytes_logical=per_page * logical,
                         prefix_hit_rate=self.prefix_hit_rate)
        return stats
