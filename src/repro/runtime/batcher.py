"""Continuous-batching serving scheduler (slot-based, vLLM-style-lite).

A fixed pool of B slots runs a single jitted decode step per tick; requests
are admitted into free slots as others finish (EOS or max_new), so the
decode batch stays full instead of draining to the slowest request —
the thing that actually determines serving throughput at scale.

Ragged-position cache contract (tested in tests/test_ragged_decode.py):
  * one shared KV cache of capacity (B, max_len) whose cache["pos"] is a
    PER-SLOT position vector (B,) int32 — slots at arbitrary, distinct
    sequence lengths decode together. Each row RoPEs its query, writes its
    K/V, and masks attention at its own position;
  * consequently step() issues exactly ONE jitted decode call per tick, no
    matter how many distinct lengths are active (the old implementation
    looped over position groups, degrading exactly when traffic is ragged);
  * a new request PREFILLS into a staging cache of its own, and its K/V
    rows are spliced into rows [0, p_len) of its slot in the shared cache
    (per-layer dynamic_update_slice); its slot's pos entry is then set to
    the prompt length. Requests that cannot fit (prompt + max_new >
    max_len) are rejected at submit();
  * idle and just-finished slots keep decoding garbage in the same call —
    their pos is pinned back to 0 and their outputs discarded, so they cost
    one masked row instead of a retrace.

Works with every decoder-family arch and any QuantConfig (incl. the full
BBAL serving stack). SSM/griffin caches are sequence-synchronous (scalar
pos, no per-slot time index) and explicitly reject ragged position vectors,
so the batcher targets the transformer family (the assigned serving
shapes' family).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.quant import linear as Q


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (P,) int32
    max_new: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg, params, qcfg: Q.QuantConfig, *,
                 n_slots: int = 4, max_len: int = 128, eos_id: int | None = None):
        assert cfg.family == "decoder", "batcher targets the decoder family"
        self.cfg, self.params, self.qcfg = cfg, params, qcfg
        self.n_slots, self.max_len, self.eos = n_slots, max_len, eos_id
        self.cache = M.init_cache(cfg, n_slots, max_len)   # cache["pos"]: (B,)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t: M.decode_step(p, cfg, c, t, qcfg))
        self.decode_calls = 0          # jitted decode invocations (1 per tick)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    @property
    def pos(self) -> list[int]:
        """Host copy of the per-slot KV position vector."""
        return [int(p) for p in jax.device_get(self.cache["pos"])]

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        # a ragged decode write past max_len is silently dropped (scatter
        # mode="drop"), so a request that cannot fit would diverge from
        # sequential decoding with no error — reject it up front instead
        need = req.prompt.shape[0] + req.max_new
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid} needs up to {need} KV rows (prompt "
                f"{req.prompt.shape[0]} + max_new {req.max_new}) but the "
                f"shared cache capacity is max_len={self.max_len}")
        self.queue.append(req)

    def _splice(self, slot: int, staged_cache, p_len: int):
        """Copy a prefilled request's K/V rows into rows [0, p_len) of
        `slot` in the shared cache (leading dims: layers..., batch, time,
        ...); the slot's pos entry is then set to p_len by _admit."""
        def one(dst, src):
            if dst.ndim < 3 or dst.shape[1] != self.n_slots:
                return dst
            # src: (L, 1|b, p_len, ...) -> write rows [0, p_len) of `slot`
            upd = jax.lax.dynamic_slice_in_dim(src, 0, 1, axis=1)
            upd = jax.lax.dynamic_slice_in_dim(upd, 0, min(p_len, dst.shape[2]), axis=2)
            return jax.lax.dynamic_update_slice(
                dst, upd.astype(dst.dtype),
                (0, slot, 0) + (0,) * (dst.ndim - 3))
        new_cache = {**self.cache,
                     "layers": jax.tree.map(one, self.cache["layers"],
                                            staged_cache["layers"])}
        if "dense" in self.cache:   # MoE archs with leading dense layers
            new_cache["dense"] = jax.tree.map(one, self.cache["dense"],
                                              staged_cache["dense"])
        self.cache = new_cache

    def _admit(self):
        for slot in range(self.n_slots):
            while self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                p_len = req.prompt.shape[0]
                logits, staged = M.prefill(self.params, self.cfg,
                                           req.prompt[None, :], self.qcfg,
                                           max_len=self.max_len)
                tok = int(jnp.argmax(logits[0]))
                req.out_tokens.append(tok)
                if len(req.out_tokens) >= req.max_new or \
                        (self.eos is not None and tok == self.eos):
                    # budget met / EOS at prefill: retire without ever
                    # occupying the slot; try the next queued request
                    req.done = True
                    self.finished.append(req)
                    continue
                self._splice(slot, staged, p_len)
                self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
                self.cache = {**self.cache,
                              "pos": self.cache["pos"].at[slot].set(p_len)}
                self.slot_req[slot] = req

    # -- the decode tick ----------------------------------------------------

    def step(self):
        """One batched decode tick: admit, ONE jitted decode over all slots
        (each at its own position), retire finished requests."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        logits, new_cache = self._decode(self.params, self.cache, self.cur_tok)
        self.decode_calls += 1
        toks = jax.device_get(jnp.argmax(logits, axis=-1))      # (B,) host
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(toks[s])
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new or \
                    (self.eos is not None and tok == self.eos):
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        # single vectorized state update: live slots take their new token and
        # advanced position; idle/finished slots are pinned back to pos 0
        live = jnp.asarray([r is not None for r in self.slot_req])
        self.cur_tok = jnp.where(live[:, None],
                                 jnp.asarray(toks, jnp.int32)[:, None],
                                 self.cur_tok)
        self.cache = {**new_cache,
                      "pos": jnp.where(live, new_cache["pos"], 0)}
        return True

    def run(self, max_ticks: int = 1000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished, ticks
