"""Continuous-batching serving scheduler (slot-based, vLLM-style-lite).

A fixed pool of B slots runs a single jitted decode step per tick; requests
are admitted into free slots as others finish (EOS or max_new), so the
decode batch stays full instead of draining to the slowest request —
the thing that actually determines serving throughput at scale.

Ragged-position cache contract (tested in tests/test_ragged_decode.py):
  * one shared KV cache whose cache["pos"] is a PER-SLOT position vector
    (B,) int32 — slots at arbitrary, distinct sequence lengths decode
    together. Each row RoPEs its query, writes its K/V, and masks attention
    at its own position;
  * consequently step() issues exactly ONE jitted decode call per tick, no
    matter how many distinct lengths are active (the old implementation
    looped over position groups, degrading exactly when traffic is ragged);
  * requests that cannot fit (prompt + max_new - 1 > max_len; the LAST
    generated token is never written back) are rejected at submit();
  * idle and just-finished slots keep decoding garbage in the same call —
    their pos is pinned back to 0 and their outputs discarded, so they cost
    one masked row instead of a retrace.

KV layouts (tested in tests/test_paged_kv.py):
  * "paged" (default) — the cache is a pool of 32-row pages shared by all
    slots (runtime/paged_kv.py): pages are allocated on ADMISSION (prompt
    pages, plus a worst-case reservation so decode appends can never fail),
    APPENDED one at a time as a slot's decode crosses a page boundary, and
    FREED on retirement. KV memory tracks the pool's actual load instead of
    n_slots * max_len, and a page is always aligned to the BBFP 32-element
    quantisation block;
  * "dense" — the original (B, max_len) slab per layer; kept as the
    reference layout and for the bench comparison.

KV storage (paged only; `kv_storage` parameter):
  * "fp" (default) — pages hold bf16 values;
  * "packed" — pages hold int8 codes + int8 per-32-block shared exponents
    in qcfg.kv_fmt (runtime/paged_kv.packed_proto): 8.25 bits/elt at
    BBFP(6,3) vs 16 for bf16, and token-for-token identical to the fp pool
    for GQA because cache writes already sit on the format grid.

Bucketed chunked prefill: a new request prefills into a staging cache whose
length is the prompt rounded up to a power-of-two BUCKET (min
`min_prefill_bucket`), so total prefill compilations are O(log max_len)
instead of O(#distinct prompt lengths) — `prefill_traces` counts them. The
next token is read at row p_len-1 (causality makes the padded tail
invisible), and the staged rows [0, p_len) splice page-by-page into the
request's pages (paged) or its slot's slab rows (dense).

Works with every decoder-family arch and any QuantConfig (incl. the full
BBAL serving stack). SSM/griffin caches are sequence-synchronous (scalar
pos, no per-slot time index) and explicitly reject ragged position vectors,
so the batcher targets the transformer family (the assigned serving
shapes' family).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime import paged_kv as PK


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (P,) int32
    max_new: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg, params, qcfg: Q.QuantConfig, *,
                 n_slots: int = 4, max_len: int = 128, eos_id: int | None = None,
                 kv_layout: str = "paged", page_size: int = PK.PAGE_SIZE,
                 n_pages: int | None = None, min_prefill_bucket: int = 16,
                 kv_storage: str = "fp"):
        assert cfg.family == "decoder", "batcher targets the decoder family"
        assert kv_layout in ("paged", "dense"), kv_layout
        assert kv_storage in ("fp", "packed"), kv_storage
        self.cfg, self.params, self.qcfg = cfg, params, qcfg
        self.n_slots, self.max_len, self.eos = n_slots, max_len, eos_id
        self.paged = kv_layout == "paged"
        self.kv_storage = kv_storage
        self.page_size = page_size
        self.min_bucket = max(1, min_prefill_bucket)
        if kv_storage == "packed":
            # packed pages store int8 codes in qcfg.kv_fmt — the storage
            # format IS the cache-quantisation format, so it must be set
            # (and the pool layout must be paged: pages = quant blocks)
            if not self.paged:
                raise ValueError("kv_storage='packed' requires kv_layout='paged'")
            if qcfg.kv_cache == "none":
                raise ValueError(
                    "kv_storage='packed' needs qcfg.kv_cache set (e.g. "
                    "'BBFP(6,3)') — it is the page storage format")
        if self.paged:
            self.max_pages = PK.pages_for(max_len, page_size)
            # default budget = dense-equivalent capacity (no overcommit);
            # pass a smaller n_pages to overcommit the pool
            self.n_pages = n_pages if n_pages is not None \
                else n_slots * self.max_pages
            self.alloc = PK.PagedKVAllocator(self.n_pages, page_size, n_slots)
            self.cache = PK.init_paged_cache(
                cfg, n_slots, max_len, n_pages=self.n_pages, page=page_size,
                storage=kv_storage,
                kv_fmt=qcfg.kv_fmt if kv_storage == "packed" else None)
        else:
            self.alloc = None
            self.cache = M.init_cache(cfg, n_slots, max_len)  # cache["pos"]: (B,)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)
        # the pre-call cache is never touched after a tick: donate it so XLA
        # aliases the new pool onto the old instead of double-buffering the
        # whole KV store every decode (no-op on CPU, real aliasing on TPU)
        self._decode = jax.jit(
            lambda p, c, t: M.decode_step(p, cfg, c, t, qcfg),
            donate_argnums=(1,))
        self.decode_calls = 0          # jitted decode invocations (1 per tick)
        self._prefill_fns: dict[int, object] = {}   # bucket -> jitted prefill
        self.prefill_traces = 0        # distinct prefill shapes compiled
        self._host_pos = [0] * n_slots  # host mirror of live slots' pos
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    @property
    def pos(self) -> list[int]:
        """Host copy of the per-slot KV position vector."""
        return [int(p) for p in jax.device_get(self.cache["pos"])]

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        # a ragged decode write past max_len is silently dropped (scatter
        # mode="drop"), so a request that cannot fit would diverge from
        # sequential decoding with no error — reject it up front instead.
        # Capacity is prompt + max_new - 1: the first token comes from
        # prefill and the LAST generated token is never written back, so a
        # request that exactly fills max_len KV rows is admissible.
        need = req.prompt.shape[0] + req.max_new - 1
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid} needs up to {need} KV rows (prompt "
                f"{req.prompt.shape[0]} + max_new {req.max_new} - 1) but the "
                f"shared cache capacity is max_len={self.max_len}")
        if self.paged and PK.pages_for(need, self.page_size) > self.n_pages:
            # can_admit() would never hold, so the request (and everything
            # FIFO-queued behind it) would spin unserved — reject up front
            raise ValueError(
                f"request {req.rid} needs {PK.pages_for(need, self.page_size)} "
                f"pages (KV rows {need} / page {self.page_size}) but the page "
                f"pool budget is n_pages={self.n_pages}")
        self.queue.append(req)

    def _bucket(self, p_len: int) -> int:
        """Prompt staging length: next power of two >= p_len (floored at
        min_bucket), so prefill shapes form an O(log max_len) ladder."""
        return max(self.min_bucket, 1 << max(p_len - 1, 0).bit_length())

    def _prefill(self, prompt: jnp.ndarray):
        """Bucketed prefill: pad the prompt to its bucket, run one jitted
        forward per BUCKET (not per length), read logits at row p_len-1
        (the padded tail is causally invisible to real rows). Returns
        (next-token logits (V,), staged cache of bucket rows)."""
        p_len = prompt.shape[0]
        bkt = self._bucket(p_len)
        fn = self._prefill_fns.get(bkt)
        if fn is None:
            mod = M.family_module(self.cfg)
            cfg, qcfg = self.cfg, self.qcfg

            def run(params, toks):
                logits, cache, _ = mod.forward(
                    params, cfg, toks, qcfg,
                    cache=mod.init_cache(cfg, 1, toks.shape[1]))
                return logits, cache

            fn = jax.jit(run)
            self._prefill_fns[bkt] = fn
            self.prefill_traces += 1
        toks = jnp.pad(prompt.astype(jnp.int32), (0, bkt - p_len))[None, :]
        logits, staged = fn(self.params, toks)
        return logits[0, p_len - 1], staged

    def _splice_dense(self, slot: int, staged_cache, p_len: int):
        """Copy a prefilled request's K/V rows into rows [0, p_len) of
        `slot` in the shared dense cache (leading dims: layers..., batch,
        time, ...); the slot's pos entry is then set to p_len by _admit."""
        def one(dst, src):
            if dst.ndim < 3 or dst.shape[1] != self.n_slots:
                return dst
            # src: (L, 1|b, >=p_len, ...) -> write rows [0, p_len) of `slot`
            upd = jax.lax.dynamic_slice_in_dim(src, 0, 1, axis=1)
            upd = jax.lax.dynamic_slice_in_dim(upd, 0, min(p_len, dst.shape[2]), axis=2)
            return jax.lax.dynamic_update_slice(
                dst, upd.astype(dst.dtype),
                (0, slot, 0) + (0,) * (dst.ndim - 3))
        new_cache = {**self.cache,
                     "layers": jax.tree.map(one, self.cache["layers"],
                                            staged_cache["layers"])}
        if "dense" in self.cache:   # MoE archs with leading dense layers
            new_cache["dense"] = jax.tree.map(one, self.cache["dense"],
                                              staged_cache["dense"])
        self.cache = new_cache

    def _admit(self):
        for slot in range(self.n_slots):
            while self.slot_req[slot] is None and self.queue:
                req = self.queue[0]
                p_len = req.prompt.shape[0]
                need_rows = max(p_len, p_len + req.max_new - 1)
                if self.paged and not self.alloc.can_admit(need_rows):
                    return   # FIFO: wait for a retirement to free pages
                self.queue.pop(0)
                logits, staged = self._prefill(req.prompt)
                tok = int(jnp.argmax(logits))
                req.out_tokens.append(tok)
                if len(req.out_tokens) >= req.max_new or \
                        (self.eos is not None and tok == self.eos):
                    # budget met / EOS at prefill: retire without ever
                    # occupying the slot (or any pages); try the next request
                    req.done = True
                    self.finished.append(req)
                    continue
                if self.paged:
                    pids = self.alloc.admit(slot, p_len, need_rows)
                    bt = self.cache["block_table"].at[slot, :len(pids)].set(
                        jnp.asarray(pids, jnp.int32))
                    self.cache = PK.splice_pages(
                        {**self.cache, "block_table": bt}, staged, pids,
                        p_len, self.page_size,
                        kv_fmt=self.qcfg.kv_fmt
                        if self.kv_storage == "packed" else None)
                else:
                    self._splice_dense(slot, staged, p_len)
                self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
                self.cache = {**self.cache,
                              "pos": self.cache["pos"].at[slot].set(p_len)}
                self._host_pos[slot] = p_len
                self.slot_req[slot] = req

    # -- the decode tick ----------------------------------------------------

    def step(self):
        """One batched decode tick: admit, ONE jitted decode over all slots
        (each at its own position), retire finished requests."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        if self.paged:
            # append a page to any slot whose write this tick crosses a page
            # boundary (infallible: covered by the admission reservation);
            # one batched table write for all appends this tick
            grown = []      # (slot, page_index, page_id)
            for s, req in enumerate(self.slot_req):
                if req is None:
                    continue
                res = self.alloc.ensure_row(s, self._host_pos[s])
                if res is not None:
                    grown.append((s, *res))
            if grown:
                rows, cols, vals = (jnp.asarray(v, jnp.int32)
                                    for v in zip(*grown))
                bt = self.cache["block_table"].at[rows, cols].set(vals)
                self.cache = {**self.cache, "block_table": bt}
        logits, new_cache = self._decode(self.params, self.cache, self.cur_tok)
        self.decode_calls += 1
        toks = jax.device_get(jnp.argmax(logits, axis=-1))      # (B,) host
        retired = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(toks[s])
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new or \
                    (self.eos is not None and tok == self.eos):
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
                retired.append(s)
        # single vectorized state update: live slots take their new token and
        # advanced position; idle/finished slots are pinned back to pos 0
        live = jnp.asarray([r is not None for r in self.slot_req])
        self.cur_tok = jnp.where(live[:, None],
                                 jnp.asarray(toks, jnp.int32)[:, None],
                                 self.cur_tok)
        self.cache = {**new_cache,
                      "pos": jnp.where(live, new_cache["pos"], 0)}
        for s in range(self.n_slots):
            self._host_pos[s] = self._host_pos[s] + 1 \
                if self.slot_req[s] is not None else 0
        if self.paged and retired:
            # return the retired slots' pages and reset their table rows
            for s in retired:
                self.alloc.release(s)
            bt = self.cache["block_table"].at[
                jnp.asarray(retired, jnp.int32)].set(self.alloc.sentinel)
            self.cache = {**self.cache, "block_table": bt}
        return True

    def run(self, max_ticks: int = 1000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished, ticks

    # -- introspection ------------------------------------------------------

    def kv_stats(self) -> dict:
        """Serving-path memory counters for the bench trajectory."""
        total = PK.kv_bytes(self.cache)
        stats = {"kv_layout": "paged" if self.paged else "dense",
                 "kv_storage": self.kv_storage,
                 "kv_store_bytes": total,
                 "kv_bytes_per_slot": total // self.n_slots}
        if self.paged:
            per_page = total // max(self.n_pages, 1)
            stats.update(pages_total=self.n_pages,
                         pages_in_use=self.alloc.used_count,
                         kv_bytes_in_use=per_page * self.alloc.used_count)
        return stats
