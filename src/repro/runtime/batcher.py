"""Continuous-batching serving scheduler (slot-based, vLLM-style-lite).

A fixed pool of B slots runs a single jitted decode step per tick; requests
are admitted into free slots as others finish (EOS or max_new), so the
decode batch stays full instead of draining to the slowest request —
the thing that actually determines serving throughput at scale.

Mechanics kept deliberately explicit (and tested):
  * one shared KV cache of capacity (B, max_len) — a new request PREFILLS
    into a staging cache of its own, and its K/V rows are spliced into the
    shared cache at its slot (per-layer dynamic_update_slice);
  * per-slot position counters double as attention masks (gqa decode
    already masks by pos), so slots at different sequence lengths coexist
    in one decode batch;
  * the decode step is jitted ONCE; admissions only touch cache buffers.

Works with every decoder-family arch and any QuantConfig (incl. the full
BBAL serving stack). SSM/griffin caches key their state differently, so the
batcher currently targets the transformer family (the assigned serving
shapes' family).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.quant import linear as Q


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (P,) int32
    max_new: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg, params, qcfg: Q.QuantConfig, *,
                 n_slots: int = 4, max_len: int = 128, eos_id: int | None = None):
        assert cfg.family == "decoder", "batcher targets the decoder family"
        self.cfg, self.params, self.qcfg = cfg, params, qcfg
        self.n_slots, self.max_len, self.eos = n_slots, max_len, eos_id
        self.cache = M.init_cache(cfg, n_slots, max_len)
        self.pos = [0] * n_slots                  # per-slot write position
        self.slot_req: list[Request | None] = [None] * n_slots
        self.cur_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t: M.decode_step(p, cfg, c, t, qcfg))
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _splice(self, slot: int, staged_cache, p_len: int):
        """Copy a prefilled request's K/V rows into `slot` of the shared
        cache (leading dims: layers..., batch, time, ...)."""
        def one(dst, src):
            if dst.ndim < 3 or dst.shape[1] != self.n_slots:
                return dst
            # src: (L, 1|b, p_len, ...) -> write rows [0, p_len) of `slot`
            upd = jax.lax.dynamic_slice_in_dim(src, 0, 1, axis=1)
            upd = jax.lax.dynamic_slice_in_dim(upd, 0, min(p_len, dst.shape[2]), axis=2)
            return jax.lax.dynamic_update_slice(
                dst, upd.astype(dst.dtype),
                (0, slot, 0) + (0,) * (dst.ndim - 3))
        new_layers = jax.tree.map(one, self.cache["layers"], staged_cache["layers"])
        self.cache = {**self.cache, "layers": new_layers}

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = req.prompt[None, :]
            logits, staged = M.prefill(self.params, self.cfg, prompt,
                                       self.qcfg, max_len=self.max_len)
            self._splice(slot, staged, req.prompt.shape[0])
            self.pos[slot] = req.prompt.shape[0]
            tok = int(jnp.argmax(logits[0]))
            req.out_tokens.append(tok)
            self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
            self.slot_req[slot] = req

    # -- the decode tick ----------------------------------------------------

    def step(self):
        """One batched decode tick: admit, decode all active slots, retire."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        # the shared cache's pos is per-batch scalar in this implementation;
        # decode each *distinct* position group together (usually 1-2 groups)
        groups: dict[int, list[int]] = {}
        for s, r in enumerate(self.slot_req):
            if r is not None:
                groups.setdefault(self.pos[s], []).append(s)
        for pos, slots in sorted(groups.items()):
            cache = {**self.cache, "pos": jnp.asarray(pos, jnp.int32)}
            logits, new_cache = self._decode(self.params, cache, self.cur_tok)
            # keep only the written rows of the participating slots
            def keep(dst, src):
                if dst.ndim < 3 or dst.shape[1] != self.n_slots:
                    return src
                mask = jnp.zeros((self.n_slots,), bool).at[jnp.asarray(slots)].set(True)
                return jnp.where(mask[None, :, None, None] if dst.ndim == 4
                                 else mask[(None, slice(None)) + (None,) * (dst.ndim - 2)],
                                 src, dst)
            self.cache = {**self.cache,
                          "layers": jax.tree.map(keep, self.cache["layers"],
                                                 new_cache["layers"])}
            for s in slots:
                req = self.slot_req[s]
                tok = int(jnp.argmax(logits[s]))
                req.out_tokens.append(tok)
                self.cur_tok = self.cur_tok.at[s, 0].set(tok)
                self.pos[s] = pos + 1
                if len(req.out_tokens) >= req.max_new or \
                        (self.eos is not None and tok == self.eos):
                    req.done = True
                    self.finished.append(req)
                    self.slot_req[s] = None
                    self.pos[s] = 0
        return True

    def run(self, max_ticks: int = 1000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished, ticks
