"""Paged KV-block allocator for the continuous batcher (vLLM-style pages).

The dense layout charges every slot for the longest request the pool might
ever see: one (B, max_len) slab per layer. Paged layout replaces the slab
with a pool of fixed-size PAGES shared by all slots:

  * a page is PAGE_SIZE = 32 KV rows — exactly ``bbfp.DEFAULT_BLOCK``, so a
    page is always aligned to the BBFP 32-element quantisation blocks of the
    source paper (arXiv:2504.15721): a packed int8+scales KV cache quantises
    whole pages without straddling block boundaries.  ``storage="packed"``
    makes that real: pages hold int8 codes (sign+flag+mantissa, one byte)
    plus int8 per-32-block shared exponents instead of bf16 — 8.25 bits/elt
    at BBFP(6,3) vs 16, numerically identical to the fp pool because cache
    writes already land on the format grid (``quant.linear.qkv_cache``);
  * each layer's physical store is (n_pages, page, heads, head_dim) — ONE
    pool, indexed the same way in every layer, so the logical->physical map
    (the block table) is shared across layers and stays (n_slots, max_pages)
    int32;
  * unallocated block-table entries hold the SENTINEL ``n_pages`` — one past
    the last physical page — so in-jit scatter writes from idle slots land
    out of bounds and are dropped (``mode="drop"``), and gather reads clamp
    to the last page, whose rows the per-slot position mask discards.

Prefix cache + copy-on-write contract: every page carries a REFCOUNT and
full pages are indexed by their page-aligned token prefix. This base class
keeps the original EXACT-CHAIN index (the key for page i is the sha256
chain digest of page i's tokens onto page i-1's key, so a key identifies
the full (i+1)*32-token prefix in O(1) bytes); the serving engine uses
``runtime.kv_manager.KVCacheManager``, which extends this class with a
RADIX TREE over page-granular token chunks plus LRU retention of retired
pages. A request whose prompt shares a 32-token-aligned prefix with a
RESIDENT sequence maps the matching pages into its block table
(``match_prefix`` -> ``admit(shared=...)``) instead of recomputing and
re-storing them; because a page is exactly one BBFP quantisation block and
packed pages are deterministic int8 codes, whole-page sharing is bit-exact.
Sharing is copy-on-write by construction rather than by copying: shared
pages are immutable (they hold only full prompt pages strictly before any
writer's position — the last PARTIAL prompt page is never shared, and the
page holding the last prompt token is also kept private so its logits can
be recomputed on admission), decode appends always land on private pages
(``ensure_row`` refcount 1), and ``release`` only returns a page to the
free list — and evicts its prefix-index entry — when its refcount reaches
zero, so either retire order of a sharing pair leaves the pool fully free.

Batcher contract (mirrors runtime/batcher.py):
  * ADMIT  — ``match_prefix`` maps any resident shared-prefix pages into
    the block table (refcount++), the remaining prompt pages are allocated,
    and the (post-prefix) prompt remainder is INCREMENTALLY CHUNK-PREFILLED
    straight into those pages (``transformer.chunk_prefill``: fixed-width
    multi-token steps whose queries attend to the already-resident paged KV
    through the block table — no dense staging cache, ONE compiled prefill
    shape). Admission only proceeds when the pool covers the pages the
    request will NEWLY allocate (worst case, minus prefix hits) on top of
    the outstanding reservations of live slots, so a decode-time append can
    never fail (no mid-flight eviction needed);
  * DECODE — stays ONE jitted call per tick: before the call the batcher
    appends a page to any slot whose next write crosses a page boundary
    (host-side, guaranteed by the reservation accounting); inside the jit
    each slot scatters its new K/V row at (block_table[slot, pos//page],
    pos % page) and attention gathers its pages back into a contiguous
    (B, max_pages*page) view masked at the slot's own position;
  * RETIRE — refcounts of the slot's pages drop; pages reaching zero return
    to the free list, and the block-table row is reset to the sentinel.

The allocator itself is host-side Python (a free list + per-slot page
lists + refcounts + the prefix index); only the block table lives on
device. ``init_paged_cache`` builds the cache pytree {"layers",
"block_table", "pos"[, "dense"]} that ``transformer.decode_step`` /
``chunk_prefill`` recognise by the presence of "block_table".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bbfp

PAGE_SIZE = bbfp.DEFAULT_BLOCK   # 32 KV rows — quantisation-block aligned


class PoolExhausted(RuntimeError):
    """No physical page is available (free list empty and nothing
    reclaimable). Never raised under the strict reservation contract —
    the relaxed-capacity engine mode (runtime/kv_manager.py) catches it
    and preempts a running sequence instead."""


def pages_for(rows: int, page: int = PAGE_SIZE) -> int:
    """Number of pages needed to hold `rows` KV rows."""
    return -(-rows // page)


class PagedKVAllocator:
    """Host-side block-table allocator over a pool of `n_pages` pages, with
    per-page refcounts and a prefix index for copy-on-write prefix sharing.

    Reservation accounting: every live slot reserves its worst-case page
    count at admission (`reserve[slot]`); `committed` is the number of free
    pages already promised to live slots' future appends. `can_admit` only
    accepts a request when the pool covers the pages it will NEWLY allocate
    (worst case minus prefix hits) on top of that, which makes `append`
    infallible for admitted requests.

    Prefix sharing: `register_prefix` indexes a slot's full prompt pages
    under cumulative page-aligned prefix keys; `match_prefix` returns the
    longest resident chain for a new prompt, and `admit(shared=...)` maps
    those pages in with refcount++ instead of allocating. `release` only
    frees a page (and evicts its index entry) at refcount zero."""

    def __init__(self, n_pages: int, page: int = PAGE_SIZE, n_slots: int = 4):
        assert n_pages >= 1 and page >= 1 and n_slots >= 1
        self.n_pages, self.page, self.n_slots = n_pages, page, n_slots
        self.free: list[int] = list(range(n_pages - 1, -1, -1))  # pop() -> 0 first
        self.pages: list[list[int]] = [[] for _ in range(n_slots)]
        self.reserve: list[int] = [0] * n_slots
        self.refcount: list[int] = [0] * n_pages
        self._prefix_index: dict = {}    # cumulative prefix key -> page id
        self._page_key: dict[int, object] = {}   # page id -> its index key

    @property
    def sentinel(self) -> int:
        """Out-of-bounds page id: scatter-dropped on write, masked on read."""
        return self.n_pages

    @property
    def free_count(self) -> int:
        return len(self.free)

    @property
    def used_count(self) -> int:
        """Physical pages allocated (shared pages count ONCE)."""
        return self.n_pages - len(self.free)

    @property
    def logical_count(self) -> int:
        """Pages as the slots see them (shared pages count per reference)."""
        return sum(len(p) for p in self.pages)

    @property
    def shared_count(self) -> int:
        """Physical pages referenced by more than one slot."""
        return sum(1 for rc in self.refcount if rc > 1)

    @property
    def committed(self) -> int:
        """Free pages already promised to live slots' future appends."""
        return sum(max(r - len(p), 0) for r, p in zip(self.reserve, self.pages))

    # -- page acquisition/return seam (KVCacheManager overrides these to
    #    add LRU retention of retired-but-still-indexed pages) --------------

    def _take_page(self) -> int:
        """Pop one physical page. Raises PoolExhausted when none is left."""
        if not self.free:
            raise PoolExhausted("page pool exhausted")
        return self.free.pop()

    def _retire_page(self, pid: int) -> bool:
        """A page just hit refcount zero on release. Returns True when the
        page went back to the free list (the base allocator always frees;
        KVCacheManager may instead retain indexed pages in its LRU)."""
        self.free.append(pid)
        key = self._page_key.pop(pid, None)
        if key is not None:
            self._prefix_index.pop(key, None)
        return True

    def can_admit(self, total_rows: int, n_shared: int = 0) -> bool:
        """Pool covers the request's NEWLY allocated worst case: its total
        page count minus the `n_shared` prefix-cache hits it maps in."""
        need = pages_for(total_rows, self.page) - n_shared
        return self.free_count - self.committed >= need

    def page_indexed(self, pid: int) -> bool:
        """Is this page reachable through the prefix index (i.e. would a
        preempted sequence find its KV cached on readmission)? The
        scheduler's cost-aware victim selection charges only NON-indexed
        rows as recompute cost."""
        return pid in self._page_key

    def match_prefix(self, keys) -> list[int]:
        """Longest resident page chain for cumulative prefix `keys` (key i
        must identify the FULL prompt prefix through page i, not just page
        i's own tokens). Callers cap `keys` so the last partial page — and
        the page holding the last prompt token — are never shared."""
        out = []
        for key in keys:
            pid = self._prefix_index.get(key)
            if pid is None:
                break
            out.append(pid)
        return out

    def register_prefix(self, keys, page_ids: list[int]) -> int:
        """Index a slot's full prompt pages (`page_ids[i]` under `keys[i]`)
        so later admissions can share them; first registration of a key
        wins. Returns the number of newly indexed pages."""
        new = 0
        for key, pid in zip(keys, page_ids):
            if key in self._prefix_index or pid in self._page_key:
                continue            # key already canonical / page indexed
            self._prefix_index[key] = pid
            self._page_key[pid] = key
            new += 1
        return new

    def _check_admit(self, prompt_rows: int, total_rows: int, shared):
        """Capacity-policy hook admit() runs before allocating: the base
        allocator demands the strict worst case; KVCacheManager swaps in
        its mode-aware check."""
        assert self.can_admit(total_rows, n_shared=len(shared)), \
            "admit() without can_admit()"

    def admit(self, slot: int, prompt_rows: int, total_rows: int,
              shared: list[int] | tuple = ()) -> list[int]:
        """Reserve `total_rows` worst-case, map in the `shared` prefix pages
        (refcount++), and allocate the rest of the prompt's pages."""
        assert not self.pages[slot], f"slot {slot} already holds pages"
        n_prompt = pages_for(prompt_rows, self.page)
        assert len(shared) <= n_prompt, (len(shared), n_prompt)
        self._check_admit(prompt_rows, total_rows, shared)
        self.reserve[slot] = pages_for(total_rows, self.page)
        for pid in shared:
            self._revive_page(pid)
            self.pages[slot].append(pid)
        for _ in range(n_prompt - len(shared)):
            pid = self._take_page()
            self.refcount[pid] = 1
            self.pages[slot].append(pid)
        return list(self.pages[slot])

    def _revive_page(self, pid: int):
        """Map a shared page into one more block table (refcount++). The
        base allocator requires the page to be actively held; KVCacheManager
        also revives refcount-zero pages out of its retired-LRU."""
        assert self.refcount[pid] >= 1, f"shared page {pid} is not resident"
        self.refcount[pid] += 1

    def ensure_row(self, slot: int, row: int) -> tuple[int, int] | None:
        """Make the page holding `row` exist; returns (slot_page_index,
        page_id) when a page was appended, None when it already existed.
        Appended pages are always PRIVATE (refcount 1, never indexed)."""
        idx = row // self.page
        if idx < len(self.pages[slot]):
            return None
        assert idx == len(self.pages[slot]), (slot, row, self.pages[slot])
        assert idx < self.reserve[slot], f"append past slot {slot} reservation"
        pid = self._take_page()    # infallible under strict reservations
        self.refcount[pid] = 1
        self.pages[slot].append(pid)
        return idx, pid

    def release(self, slot: int) -> list[int]:
        """Drop the retired slot's references; pages reaching refcount zero
        return to the free list (their prefix-index entries evicted) and are
        returned (for block-table reset). Shared pages survive until their
        last reader retires — either retire order of a sharing pair leaves
        the pool fully free."""
        dropped = []
        for pid in self.pages[slot]:
            self.refcount[pid] -= 1
            assert self.refcount[pid] >= 0, f"page {pid} over-released"
            if self.refcount[pid] == 0:
                dropped.append(pid)
        self.pages[slot] = []
        for pid in reversed(dropped):  # keeps the base free-list pop order
            self._retire_page(pid)
        self.reserve[slot] = 0
        return dropped


def init_block_table(n_slots: int, max_pages: int, sentinel: int) -> jnp.ndarray:
    return jnp.full((n_slots, max_pages), sentinel, jnp.int32)


def packed_proto(proto):
    """Map an fp page-pool proto to PACKED storage: every (n_pages, page,
    ..., d) fp leaf becomes {"q": int8 same shape, "exp": int8 (..., d/32
    rounded up)} — int8 codes (sign+flag+mantissa in one byte, see
    ``bbfp.pack_kv``) plus the per-32-block shared exponent. 8 + 8/32 = 8.25
    bits/elt stored instead of 16 (bf16): the serving KV read/write traffic
    drops ~2x at BBFP(6,3) with zero numerical change (values already sit on
    the format grid at cache write)."""
    def one(x):
        nb = -(-x.shape[-1] // bbfp.DEFAULT_BLOCK)
        return {"q": jnp.zeros(x.shape, jnp.int8),
                "exp": jnp.zeros(x.shape[:-1] + (nb,), jnp.int8)}
    return jax.tree.map(one, proto)


def packed4_proto(proto):
    """Map an fp page-pool proto to PACKED4 (sub-byte) storage: every
    (n_pages, page, ..., d) fp leaf becomes {"q": int8 (..., d/2), "exp":
    int8 (..., d/32 rounded up)} — two sign-magnitude nibble codes per byte
    (``bbfp.pack_kv_nibble``) plus the per-32-block shared exponent.
    4 + 8/32 = 4.25 bits/elt stored instead of 16 (bf16), ~0.27x the KV
    bytes. Pages stay one quantisation block, so snapshot/restore and
    prefix sharing move nibble pages verbatim (bit-exact). Decoding only
    happens inside the fused paged-attention kernel — the jnp fallback
    exists for parity tests but re-materialises the view per tick."""
    def one(x):
        assert x.shape[-1] % 2 == 0, \
            f"packed4 needs an even trailing dim: {x.shape}"
        nb = -(-x.shape[-1] // bbfp.DEFAULT_BLOCK)
        return {"q": jnp.zeros(x.shape[:-1] + (x.shape[-1] // 2,), jnp.int8),
                "exp": jnp.zeros(x.shape[:-1] + (nb,), jnp.int8)}
    return jax.tree.map(one, proto)


def init_paged_cache(cfg, n_slots: int, max_len: int, *,
                     n_pages: int, page: int = PAGE_SIZE,
                     storage: str = "fp", kv_fmt=None):
    """Paged decoder cache: per-layer stores of shape (L, n_pages, page, ...)
    plus the shared block table. Presence of "block_table" is what switches
    decode_step/attention onto the paged gather/scatter path.

    storage="packed" keeps pages as int8 mantissa codes + shared exponents
    (``packed_proto``); `kv_fmt` is the storage QuantFormat (must fit the
    int8 code, e.g. BBFP(6,3) — ``bbfp.kv_packable``). storage="packed4"
    halves that again — two nibble codes per byte (``packed4_proto``;
    `kv_fmt` must pass ``bbfp.kv_packable4``, e.g. BBFP(2,1)); GQA only,
    since the nibble decode lives in the fused GQA attention kernel."""
    from repro.models import model as M          # avoid import cycle
    mod = M.family_module(cfg)
    if not hasattr(mod, "cache_proto"):
        raise NotImplementedError(
            f"paged KV targets the transformer family, not {cfg.family!r}")
    assert storage in ("fp", "packed", "packed4"), storage
    max_pages = pages_for(max_len, page)
    n_dense = cfg.moe.first_dense if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense
    proto = mod.cache_proto(cfg, n_pages, page)  # (n_pages, page, ...)
    if storage == "packed":
        if kv_fmt is None or not bbfp.kv_packable(kv_fmt):
            raise ValueError(
                f"storage='packed' needs an int8-codable kv_fmt "
                f"(bbfp m<=6 / bfp m<=7), got {getattr(kv_fmt, 'name', kv_fmt)}")
        proto = packed_proto(proto)
    elif storage == "packed4":
        if kv_fmt is None or not bbfp.kv_packable4(kv_fmt):
            raise ValueError(
                f"storage='packed4' needs a nibble-codable kv_fmt "
                f"(bbfp m<=2 / bfp m<=3), got {getattr(kv_fmt, 'name', kv_fmt)}")
        if cfg.mla is not None:
            raise ValueError(
                "storage='packed4' targets GQA pools — the nibble decode "
                "lives in the fused GQA paged-attention kernel; MLA latent "
                "caches use storage='packed'")
        proto = packed4_proto(proto)
    stack = lambda n: jax.tree.map(
        lambda x: jnp.zeros((n,) + x.shape, x.dtype), proto)
    cache = {"layers": stack(n_scan),
             "block_table": init_block_table(n_slots, max_pages, n_pages),
             "pos": jnp.zeros((n_slots,), jnp.int32)}
    if n_dense:
        cache["dense"] = stack(n_dense)
    return cache


def kv_bytes(cache) -> int:
    """Total bytes held by the KV stores of a cache pytree (dense or paged)."""
    leaves = jax.tree.leaves(cache["layers"])
    total = sum(x.size * x.dtype.itemsize for x in leaves)
    if "dense" in cache:
        total += sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(cache["dense"]))
    return total


def kv_bytes_shard(cache) -> int:
    """Bytes one device holds for the KV stores: the per-shard slice of every
    sharded leaf, the full leaf for replicated ones. Equals ``kv_bytes`` on a
    single device / unsharded cache."""
    def one(x):
        shape = x.sharding.shard_shape(x.shape) if hasattr(x, "sharding") \
            else x.shape
        n = 1
        for d in shape:
            n *= d
        return n * x.dtype.itemsize
    total = sum(one(x) for x in jax.tree.leaves(cache["layers"]))
    if "dense" in cache:
        total += sum(one(x) for x in jax.tree.leaves(cache["dense"]))
    return total


def _pool_spec(path, leaf, model_size: int):
    """PartitionSpec for one page-pool leaf under HEAD-dim tensor
    parallelism (``shard_axis="heads"``).

    GQA pools — fp {"k","v"} (L, n_pages, page, KH, hd) and their packed
    {"q","exp"} sub-leaves — all carry the KV-heads axis at dim -2 with
    ndim 5, so they shard along "model" there, matching SERVE_RULES'
    "heads" rule for the attention computation. Everything else (MLA's
    ckv/krope, whose dim -2 is the PAGE axis — a quantisation block must
    never straddle shards — plus block table and positions) replicates.

    A KV-heads axis that does NOT divide the model-axis size is a loud
    error rather than a silent replicate: head-dim sharding fundamentally
    needs ``kv_heads % tp == 0``, and the fix is the page-dim mode
    (``shard_axis="pages"``, the fused-kernel path), which has no head
    divisibility requirement at all."""
    from jax.sharding import PartitionSpec as P
    keys = {getattr(k, "key", None) for k in path}
    if model_size > 1 and leaf.ndim >= 5 and keys & {"k", "v"}:
        if leaf.shape[-2] % model_size != 0:
            raise ValueError(
                f"head-dim KV sharding needs kv_heads % tp == 0, got "
                f"kv_heads={leaf.shape[-2]} tp={model_size}. Page-dim "
                f"sharding has no head divisibility requirement: use "
                f"shard_paged_cache(..., shard_axis='pages') — the "
                f"--paged-attn fused serving path.")
        return P(*([None] * (leaf.ndim - 2)), "model", None)
    return P()


def translate_block_table(block_table, local_n: int, shard):
    """Global block table -> this shard's LOCAL table under page-dim
    sharding. Shard s owns the contiguous global pages
    [s*local_n, (s+1)*local_n); a global id it owns maps to
    ``id - s*local_n``, every other entry — another shard's page OR the
    global sentinel ``n_shards*local_n`` — maps to the LOCAL sentinel
    ``local_n``, so the kernel's existing clamp+mask semantics kill it.
    `shard` may be a traced ``axis_index`` (inside shard_map) or an int."""
    bt = jnp.asarray(block_table, jnp.int32)
    lo = jnp.asarray(shard, jnp.int32) * local_n
    local = bt - lo
    return jnp.where((local >= 0) & (local < local_n), local, local_n)


def global_page_id(local_id, local_n: int, shard):
    """Inverse of ``translate_block_table`` for OWNED entries: shard s's
    local page i is global page ``s*local_n + i``. The local sentinel
    ``local_n`` has no single global preimage (it covers every non-local
    id) and is mapped to the GLOBAL sentinel of a pool with ``local_n``
    pages per shard — callers that need exact round-trips must only feed
    owned ids."""
    lid = jnp.asarray(local_id, jnp.int32)
    return jnp.where(lid >= local_n, -1, lid + jnp.asarray(shard, jnp.int32) * local_n)


def shard_paged_cache(cache, mesh, shard_axis: str = "heads"):
    """Commit a paged cache pytree to `mesh`. Block table / positions stay
    replicated in both modes, so the host-side Scheduler and allocator
    bookkeeping never change. No-op-shaped for mesh=None.

    shard_axis="heads" (default, the jnp `_paged_view` TP path): GQA page
    pools shard their KV-heads axis along "model" (one BBFP block per page
    stays intact on each shard); requires ``kv_heads % tp == 0``.

    shard_axis="pages" (the fused-kernel flash-decoding path): EVERY pool
    leaf — fp, packed, packed4, MLA latents alike — shards its n_pages
    axis (dim 1 of the (L, n_pages, page, ...) layer stacks) along
    "model": each device owns a contiguous slice of the physical page
    pool and attention runs per-shard over local pages with a log-sum-exp
    merge (``kernels.paged_attention.merge_partials``). No head
    divisibility requirement; needs ``n_pages % tp == 0`` (the batcher
    rounds the pool up)."""
    if mesh is None:
        return cache
    assert shard_axis in ("heads", "pages"), shard_axis
    from jax.sharding import NamedSharding, PartitionSpec as P
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    def put(subtree):
        leaves, treedef = jax.tree_util.tree_flatten_with_path(subtree)
        out = []
        for path, leaf in leaves:
            if shard_axis == "pages" and model_size > 1:
                if leaf.shape[1] % model_size != 0:
                    raise ValueError(
                        f"page-dim KV sharding needs n_pages % tp == 0, got "
                        f"n_pages={leaf.shape[1]} tp={model_size} (the "
                        f"batcher rounds the pool size up — reach here only "
                        f"with a hand-built pool)")
                spec = P(None, "model")
            else:
                spec = _pool_spec(path, leaf, model_size)
            out.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
        return jax.tree_util.tree_unflatten(treedef, out)

    rep = NamedSharding(mesh, P())
    out = {"layers": put(cache["layers"]),
           "block_table": jax.device_put(cache["block_table"], rep),
           "pos": jax.device_put(cache["pos"], rep)}
    if "dense" in cache:
        out["dense"] = put(cache["dense"])
    return out
