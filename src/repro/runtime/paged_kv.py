"""Paged KV-block allocator for the continuous batcher (vLLM-style pages).

The dense layout charges every slot for the longest request the pool might
ever see: one (B, max_len) slab per layer. Paged layout replaces the slab
with a pool of fixed-size PAGES shared by all slots:

  * a page is PAGE_SIZE = 32 KV rows — exactly ``bbfp.DEFAULT_BLOCK``, so a
    page is always aligned to the BBFP 32-element quantisation blocks of the
    source paper (arXiv:2504.15721): a packed int8+scales KV cache quantises
    whole pages without straddling block boundaries.  ``storage="packed"``
    makes that real: pages hold int8 codes (sign+flag+mantissa, one byte)
    plus int8 per-32-block shared exponents instead of bf16 — 8.25 bits/elt
    at BBFP(6,3) vs 16, numerically identical to the fp pool because cache
    writes already land on the format grid (``quant.linear.qkv_cache``);
  * each layer's physical store is (n_pages, page, heads, head_dim) — ONE
    pool, indexed the same way in every layer, so the logical->physical map
    (the block table) is shared across layers and stays (n_slots, max_pages)
    int32;
  * unallocated block-table entries hold the SENTINEL ``n_pages`` — one past
    the last physical page — so in-jit scatter writes from idle slots land
    out of bounds and are dropped (``mode="drop"``), and gather reads clamp
    to the last page, whose rows the per-slot position mask discards.

Batcher contract (mirrors runtime/batcher.py):
  * ADMIT  — pages for the prompt are allocated up front and the prefilled
    rows are spliced page-by-page into them; admission only proceeds when
    the pool can cover the request's WORST-CASE page count on top of the
    outstanding reservations of live slots, so a decode-time append can
    never fail (no mid-flight eviction needed);
  * DECODE — stays ONE jitted call per tick: before the call the batcher
    appends a page to any slot whose next write crosses a page boundary
    (host-side, guaranteed by the reservation accounting); inside the jit
    each slot scatters its new K/V row at (block_table[slot, pos//page],
    pos % page) and attention gathers its pages back into a contiguous
    (B, max_pages*page) view masked at the slot's own position;
  * RETIRE — the slot's pages return to the free list and its block-table
    row is reset to the sentinel.

The allocator itself is host-side Python (a free list + per-slot page
lists); only the block table lives on device. ``init_paged_cache`` builds
the cache pytree {"layers", "block_table", "pos"[, "dense"]} that
``transformer.decode_step`` recognises by the presence of "block_table".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bbfp

PAGE_SIZE = bbfp.DEFAULT_BLOCK   # 32 KV rows — quantisation-block aligned


def pages_for(rows: int, page: int = PAGE_SIZE) -> int:
    """Number of pages needed to hold `rows` KV rows."""
    return -(-rows // page)


class PagedKVAllocator:
    """Host-side block-table allocator over a pool of `n_pages` pages.

    Reservation accounting: every live slot reserves its worst-case page
    count at admission (`reserve[slot]`); `committed` is the number of free
    pages already promised to live slots' future appends. `can_admit` only
    accepts a request when the pool covers its worst case on top of that,
    which makes `append` infallible for admitted requests.
    """

    def __init__(self, n_pages: int, page: int = PAGE_SIZE, n_slots: int = 4):
        assert n_pages >= 1 and page >= 1 and n_slots >= 1
        self.n_pages, self.page, self.n_slots = n_pages, page, n_slots
        self.free: list[int] = list(range(n_pages - 1, -1, -1))  # pop() -> 0 first
        self.pages: list[list[int]] = [[] for _ in range(n_slots)]
        self.reserve: list[int] = [0] * n_slots

    @property
    def sentinel(self) -> int:
        """Out-of-bounds page id: scatter-dropped on write, masked on read."""
        return self.n_pages

    @property
    def free_count(self) -> int:
        return len(self.free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self.free)

    @property
    def committed(self) -> int:
        """Free pages already promised to live slots' future appends."""
        return sum(max(r - len(p), 0) for r, p in zip(self.reserve, self.pages))

    def can_admit(self, total_rows: int) -> bool:
        return self.free_count - self.committed >= pages_for(total_rows, self.page)

    def admit(self, slot: int, prompt_rows: int, total_rows: int) -> list[int]:
        """Reserve `total_rows` worst-case and allocate the prompt's pages."""
        assert not self.pages[slot], f"slot {slot} already holds pages"
        assert self.can_admit(total_rows), "admit() without can_admit()"
        self.reserve[slot] = pages_for(total_rows, self.page)
        for _ in range(pages_for(prompt_rows, self.page)):
            self.pages[slot].append(self.free.pop())
        return list(self.pages[slot])

    def ensure_row(self, slot: int, row: int) -> tuple[int, int] | None:
        """Make the page holding `row` exist; returns (slot_page_index,
        page_id) when a page was appended, None when it already existed."""
        idx = row // self.page
        if idx < len(self.pages[slot]):
            return None
        assert idx == len(self.pages[slot]), (slot, row, self.pages[slot])
        assert idx < self.reserve[slot], f"append past slot {slot} reservation"
        pid = self.free.pop()      # infallible: covered by `committed`
        self.pages[slot].append(pid)
        return idx, pid

    def release(self, slot: int) -> list[int]:
        """Free a retired slot's pages; returns them (for block-table reset)."""
        freed, self.pages[slot] = self.pages[slot], []
        self.free.extend(reversed(freed))
        self.reserve[slot] = 0
        return freed


def init_block_table(n_slots: int, max_pages: int, sentinel: int) -> jnp.ndarray:
    return jnp.full((n_slots, max_pages), sentinel, jnp.int32)


def packed_proto(proto):
    """Map an fp page-pool proto to PACKED storage: every (n_pages, page,
    ..., d) fp leaf becomes {"q": int8 same shape, "exp": int8 (..., d/32
    rounded up)} — int8 codes (sign+flag+mantissa in one byte, see
    ``bbfp.pack_kv``) plus the per-32-block shared exponent. 8 + 8/32 = 8.25
    bits/elt stored instead of 16 (bf16): the serving KV read/write traffic
    drops ~2x at BBFP(6,3) with zero numerical change (values already sit on
    the format grid at cache write)."""
    def one(x):
        nb = -(-x.shape[-1] // bbfp.DEFAULT_BLOCK)
        return {"q": jnp.zeros(x.shape, jnp.int8),
                "exp": jnp.zeros(x.shape[:-1] + (nb,), jnp.int8)}
    return jax.tree.map(one, proto)


def init_paged_cache(cfg, n_slots: int, max_len: int, *,
                     n_pages: int, page: int = PAGE_SIZE,
                     storage: str = "fp", kv_fmt=None):
    """Paged decoder cache: per-layer stores of shape (L, n_pages, page, ...)
    plus the shared block table. Presence of "block_table" is what switches
    decode_step/attention onto the paged gather/scatter path.

    storage="packed" keeps pages as int8 mantissa codes + shared exponents
    (``packed_proto``); `kv_fmt` is the storage QuantFormat (must fit the
    int8 code, e.g. BBFP(6,3) — ``bbfp.kv_packable``)."""
    from repro.models import model as M          # avoid import cycle
    mod = M.family_module(cfg)
    if not hasattr(mod, "cache_proto"):
        raise NotImplementedError(
            f"paged KV targets the transformer family, not {cfg.family!r}")
    assert storage in ("fp", "packed"), storage
    max_pages = pages_for(max_len, page)
    n_dense = cfg.moe.first_dense if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense
    proto = mod.cache_proto(cfg, n_pages, page)  # (n_pages, page, ...)
    if storage == "packed":
        if kv_fmt is None or not bbfp.kv_packable(kv_fmt):
            raise ValueError(
                f"storage='packed' needs an int8-codable kv_fmt "
                f"(bbfp m<=6 / bfp m<=7), got {getattr(kv_fmt, 'name', kv_fmt)}")
        proto = packed_proto(proto)
    stack = lambda n: jax.tree.map(
        lambda x: jnp.zeros((n,) + x.shape, x.dtype), proto)
    cache = {"layers": stack(n_scan),
             "block_table": init_block_table(n_slots, max_pages, n_pages),
             "pos": jnp.zeros((n_slots,), jnp.int32)}
    if n_dense:
        cache["dense"] = stack(n_dense)
    return cache


def splice_pages(cache, staged, page_ids: list[int], p_len: int, page: int,
                 kv_fmt=None):
    """Copy a prefilled request's rows [0, p_len) from its dense staging
    cache into the physical pages `page_ids` (host-driven, page-granular:
    chunk i of the prompt lands in page_ids[i]). ONE batched scatter per KV
    leaf — not one full-pool copy per page. Returns the updated cache.

    PACKED pools ({"q","exp"} leaves) encode the staged fp rows into int8
    codes + exponents in `kv_fmt` before the scatter — exact for rows the
    prefill already wrote through the qkv_cache grid.

    Rows past p_len in the last page are zero-filled; they sit beyond every
    reader's position mask and decode overwrites them as the slot grows."""
    pids = jnp.asarray(page_ids, jnp.int32)
    total = len(page_ids) * page

    def paged_rows(src):
        # src: (L, 1|b, >=p_len, ...) -> (L, len(page_ids), page, ...)
        rows = src[:, :1, :min(p_len, total)]
        if rows.shape[2] < total:
            widths = [(0, 0)] * rows.ndim
            widths[2] = (0, total - rows.shape[2])
            rows = jnp.pad(rows, widths)
        return rows.reshape(src.shape[0], len(page_ids), page, *src.shape[3:])

    def one(dst, src):
        rows = paged_rows(src)
        if isinstance(dst, dict):   # packed pool: quantise on splice
            enc = bbfp.pack_kv(rows.astype(jnp.float32), kv_fmt)
            return {"q": dst["q"].at[:, pids].set(enc["q"]),
                    "exp": dst["exp"].at[:, pids].set(enc["exp"])}
        return dst.at[:, pids].set(rows.astype(dst.dtype))

    is_pool = lambda x: isinstance(x, dict) and "q" in x
    new_cache = {**cache,
                 "layers": jax.tree.map(one, cache["layers"], staged["layers"],
                                        is_leaf=is_pool)}
    if "dense" in cache:
        new_cache["dense"] = jax.tree.map(one, cache["dense"], staged["dense"],
                                          is_leaf=is_pool)
    return new_cache


def kv_bytes(cache) -> int:
    """Total bytes held by the KV stores of a cache pytree (dense or paged)."""
    leaves = jax.tree.leaves(cache["layers"])
    total = sum(x.size * x.dtype.itemsize for x in leaves)
    if "dense" in cache:
        total += sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(cache["dense"]))
    return total
