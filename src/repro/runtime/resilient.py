"""Fault-tolerant training runtime.

  * FailureInjector — deterministic chaos monkey: raises at configured steps
    (stands in for preemption / device loss in CI).
  * resilient_train_loop — checkpoint every N steps (async), on failure
    restore the latest checkpoint and *re-enter the loop at the restored
    step*; the data pipeline is (seed, step)-deterministic so the replayed
    batches are identical. max_restarts bounds the retry budget.
  * StragglerMonitor — per-step wall-time EWMA + variance; steps slower than
    mean + k*sigma are flagged. On a real fleet the flag feeds the
    controller (hot-spare swap / re-shard); here it is surfaced in metrics
    and tested with synthetic delays.

Elastic scaling: restart with a different mesh works because checkpoints
are mesh-agnostic (see repro.checkpoint) — the loop takes the current
sharding set as input and device_puts the restored state accordingly.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
# The fault primitives were hoisted to runtime/faults.py (shared with the
# serving chaos hooks); these re-exports keep every historical import path
# (repro.runtime.resilient.FailureInjector etc.) working unchanged.
from repro.runtime.faults import (  # noqa: F401
    FailureInjector, InjectedFailure, StragglerMonitor,
)


def resilient_train_loop(*, init_state, step_fn: Callable, batch_fn: Callable,
                         n_steps: int, ckpt_dir: str, ckpt_every: int = 10,
                         injector: FailureInjector | None = None,
                         monitor: StragglerMonitor | None = None,
                         max_restarts: int = 5, log_every: int = 0):
    """Run step_fn(state, batch) -> (state, metrics) with restart-on-failure.

    Returns (state, history dict). state must be a pytree; batch_fn(step)
    must be deterministic in step.
    """
    ckpt = AsyncCheckpointer(ckpt_dir)
    monitor = monitor or StragglerMonitor()
    state = init_state
    start = 0
    restored = latest_step(ckpt_dir)
    if restored is not None:
        _, state = restore_checkpoint(ckpt_dir, init_state)
        start = restored
    history = {"loss": [], "restarts": 0, "stragglers": monitor.flagged}

    step = start
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            if injector:
                injector.maybe_fail(step)
            state, metrics = step_fn(state, batch_fn(step))
            jax.block_until_ready(jax.tree.leaves(state)[0])
            monitor.observe(step, time.perf_counter() - t0)
            history["loss"].append(float(metrics["loss"]))
            if log_every and step % log_every == 0:
                print(f"step {step} loss {float(metrics['loss']):.4f}")
            step += 1
            if step % ckpt_every == 0:
                ckpt.save(step, state)
        except InjectedFailure:
            history["restarts"] += 1
            if history["restarts"] > max_restarts:
                raise
            ckpt.wait()
            restored = latest_step(ckpt_dir)
            if restored is not None:
                _, state = restore_checkpoint(ckpt_dir, init_state)
                step = restored
            else:
                state, step = init_state, 0
    ckpt.wait()
    return state, history
