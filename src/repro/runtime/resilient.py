"""Fault-tolerant training runtime.

  * FailureInjector — deterministic chaos monkey: raises at configured steps
    (stands in for preemption / device loss in CI).
  * resilient_train_loop — checkpoint every N steps (async), on failure
    restore the latest checkpoint and *re-enter the loop at the restored
    step*; the data pipeline is (seed, step)-deterministic so the replayed
    batches are identical. max_restarts bounds the retry budget.
  * StragglerMonitor — per-step wall-time EWMA + variance; steps slower than
    mean + k*sigma are flagged. On a real fleet the flag feeds the
    controller (hot-spare swap / re-shard); here it is surfaced in metrics
    and tested with synthetic delays.

Elastic scaling: restart with a different mesh works because checkpoints
are mesh-agnostic (see repro.checkpoint) — the loop takes the current
sharding set as input and device_puts the restored state accordingly.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclass
class StragglerMonitor:
    alpha: float = 0.2
    k_sigma: float = 3.0
    warmup: int = 5
    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._mean = dt if self._n == 1 else (self._mean + dt) / 2
            return False
        d = dt - self._mean
        is_straggler = d > self.k_sigma * max(self._var, 1e-12) ** 0.5 and self._n > self.warmup
        self._mean += self.alpha * d
        self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler


def resilient_train_loop(*, init_state, step_fn: Callable, batch_fn: Callable,
                         n_steps: int, ckpt_dir: str, ckpt_every: int = 10,
                         injector: FailureInjector | None = None,
                         monitor: StragglerMonitor | None = None,
                         max_restarts: int = 5, log_every: int = 0):
    """Run step_fn(state, batch) -> (state, metrics) with restart-on-failure.

    Returns (state, history dict). state must be a pytree; batch_fn(step)
    must be deterministic in step.
    """
    ckpt = AsyncCheckpointer(ckpt_dir)
    monitor = monitor or StragglerMonitor()
    state = init_state
    start = 0
    restored = latest_step(ckpt_dir)
    if restored is not None:
        _, state = restore_checkpoint(ckpt_dir, init_state)
        start = restored
    history = {"loss": [], "restarts": 0, "stragglers": monitor.flagged}

    step = start
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            if injector:
                injector.maybe_fail(step)
            state, metrics = step_fn(state, batch_fn(step))
            jax.block_until_ready(jax.tree.leaves(state)[0])
            monitor.observe(step, time.perf_counter() - t0)
            history["loss"].append(float(metrics["loss"]))
            if log_every and step % log_every == 0:
                print(f"step {step} loss {float(metrics['loss']):.4f}")
            step += 1
            if step % ckpt_every == 0:
                ckpt.save(step, state)
        except InjectedFailure:
            history["restarts"] += 1
            if history["restarts"] > max_restarts:
                raise
            ckpt.wait()
            restored = latest_step(ckpt_dir)
            if restored is not None:
                _, state = restore_checkpoint(ckpt_dir, init_state)
                step = restored
            else:
                state, step = init_state, 0
    ckpt.wait()
    return state, history
