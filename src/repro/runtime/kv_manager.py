"""KVCacheManager: paged-KV ownership layer of the serving engine.

One of the three engine layers (Scheduler / KVCacheManager / ModelRunner —
see runtime/__init__.py for the contract). The manager extends the host-side
``PagedKVAllocator`` bookkeeping (free list, refcounts, per-slot page lists,
reservations) with the two policies the monolithic batcher could not
express:

RADIX PREFIX TREE. The exact-chain hash index (``PagedKVAllocator
.match_prefix`` over chained sha256 digests) is replaced by a radix tree
over PAGE-GRANULAR TOKEN CHUNKS: each node is one page-size chunk of
tokens, its path from the root spells the full token prefix, and the node
pins the physical page holding that chunk's KV. Because a page is exactly
one BBFP quantisation block, a node's page is bit-identical for every
request that reaches it, so ``match_tokens`` returns the longest common
page-aligned prefix of ANY indexed sequence — resident or recently
retired — not just an exactly re-registered chain. Matching compares raw
token chunks (no hashing, no collision argument needed) and is O(pages)
per lookup.

LRU RETENTION. ``release`` no longer frees an indexed page the moment its
refcount reaches zero: it parks the page (content intact) in an LRU of
RETIRED pages, still reachable through the radix tree, and only actually
reclaims it — evicting its node — when ``_take_page`` finds the free list
empty. A request arriving just after its prefix-mate retired therefore
still shares the pages (``_revive_page`` lifts them out of the LRU,
refcount 0 -> 1). Eviction walks the LRU oldest-first and only takes nodes
with no resident children, so a cached chain is reclaimed leaf-up and an
active subtree is never stranded (readers hold refcounts on their whole
path, hence a retired node can never have an active child).

CAPACITY MODES. ``strict_reserve=True`` (default) keeps the monolith's
contract: admission reserves the worst-case page count so decode appends
are infallible. ``strict_reserve=False`` is the preemption mode used by
``Scheduler(preempt=True)``: admission reserves only the prompt's pages
(the pool can oversubscribe) and ``ensure_row`` may raise ``PoolExhausted``,
which the scheduler resolves by preempting a running sequence.
``preempt_release`` registers the victim's full written pages (prompt AND
generated rows — deterministic greedy KV is canonical for its token
prefix) before releasing them, so a quick readmission finds most of its
state still cached instead of recomputing it.
"""
from __future__ import annotations

import collections

import numpy as np

from repro.runtime import paged_kv as PK


class _RadixNode:
    """One page-size token chunk; the path from the root is the prefix."""
    __slots__ = ("chunk", "parent", "children", "page_id")

    def __init__(self, chunk, parent, page_id):
        self.chunk, self.parent, self.page_id = chunk, parent, page_id
        self.children: dict[tuple, _RadixNode] = {}


class KVCacheManager(PK.PagedKVAllocator):
    """Radix-indexed, LRU-retaining page manager (host-side, no jax)."""

    def __init__(self, n_pages: int, page: int = PK.PAGE_SIZE,
                 n_slots: int = 4, *, strict_reserve: bool = True,
                 retain: bool = True):
        super().__init__(n_pages, page, n_slots)
        self.strict_reserve = strict_reserve
        self.retain = retain                    # LRU retention of retired pages
        self.root = _RadixNode(None, None, None)
        self._node_of_page: dict[int, _RadixNode] = {}
        self._lru: collections.OrderedDict[int, _RadixNode] = \
            collections.OrderedDict()           # retired pages, oldest first
        self.evictions = 0                      # retired pages reclaimed
        self.revivals = 0                       # retired pages re-shared
        self.restored_pages = 0                 # pages revived by restore_kv

    # -- capacity ----------------------------------------------------------

    @property
    def cached_count(self) -> int:
        """Retired pages whose content is still resident (reclaimable)."""
        return len(self._lru)

    @property
    def allocatable(self) -> int:
        """Pages a new allocation can obtain: free + evictable retired."""
        return len(self.free) + len(self._lru)

    @property
    def used_count(self) -> int:
        """ACTIVE pages (refcount >= 1). Retired-but-cached pages are
        reclaimable cache, not load, and are reported separately."""
        return self.n_pages - len(self.free) - len(self._lru)

    @property
    def radix_size(self) -> int:
        """Indexed pages (= radix tree nodes, root excluded)."""
        return len(self._node_of_page)

    def can_admit(self, total_rows: int, n_shared: int = 0) -> bool:
        """Count-only compat API (the engine uses ``can_admit_rows``,
        which takes the matched chain itself): it cannot know how many of
        the `n_shared` pages are retired-LRU entries whose revival
        consumes `allocatable`, so it charges the worst case — every
        shared page that COULD be cached is."""
        avail = self.allocatable - min(n_shared, self.cached_count)
        return avail - self.committed >= \
            PK.pages_for(total_rows, self.page) - n_shared

    def can_admit_rows(self, prompt_rows: int, total_rows: int,
                       shared=()) -> bool:
        """Mode-aware admission check: strict mode charges the worst case
        plus outstanding reservations (appends stay infallible); relaxed
        mode charges only the prompt's pages (preemption covers appends).
        `shared` is the matched page chain itself, not a count: a shared
        page currently RETIRED (refcount 0) still sits in `allocatable`,
        and reviving it consumes that slack — it must be charged."""
        n_cached = sum(1 for pid in shared if self.refcount[pid] == 0)
        avail = self.allocatable - n_cached
        if self.strict_reserve:
            return avail - self.committed >= \
                PK.pages_for(total_rows, self.page) - len(shared)
        # relaxed: charge the prompt pages PLUS the page of the first
        # decode write (row `prompt_rows`) — admitting a sequence that
        # cannot write a single row before preempting is pure churn
        rows_chk = min(total_rows, prompt_rows + 1)
        return avail >= PK.pages_for(rows_chk, self.page) - len(shared)

    # -- page acquisition overrides (LRU retention) ------------------------

    def _take_page(self) -> int:
        if self.free:
            return self.free.pop()
        return self._evict_one()

    def _evict_one(self) -> int:
        """Reclaim the oldest retired page with no resident children (a
        cached chain is evicted leaf-up; active subtrees are unreachable
        here because readers pin their whole path)."""
        for pid, node in self._lru.items():
            if not node.children:
                del self._lru[pid]
                self._drop_node(node)
                self.evictions += 1
                return pid
        raise PK.PoolExhausted("page pool exhausted (all pages active)")

    def _retire_page(self, pid: int) -> bool:
        node = self._node_of_page.get(pid)
        if node is not None and self.retain:
            self._lru[pid] = node               # park at the MRU end
            self._lru.move_to_end(pid)
            return False
        if node is not None:
            self._drop_node(node)
        self.free.append(pid)
        return True

    def _revive_page(self, pid: int):
        if self.refcount[pid] == 0:             # retired -> active again
            assert pid in self._lru, f"page {pid} is not resident"
            del self._lru[pid]
            self.refcount[pid] = 1
            self.revivals += 1
        else:
            self.refcount[pid] += 1

    def _drop_node(self, node: _RadixNode):
        assert not node.children, "evicting a radix node with live children"
        node.parent.children.pop(node.chunk, None)
        self._node_of_page.pop(node.page_id, None)

    # -- admission ---------------------------------------------------------

    def _check_admit(self, prompt_rows: int, total_rows: int, shared):
        """The base allocator's admit() body is reused as-is; only the
        capacity policy differs (mode-aware, chain-aware)."""
        assert self.can_admit_rows(prompt_rows, total_rows, shared), \
            "admit() without can_admit_rows()"

    # -- the radix prefix index --------------------------------------------

    def match_tokens(self, tokens, max_pages: int | None = None) -> list[int]:
        """Longest indexed page chain for `tokens` (page-granular walk).
        Callers cap `max_pages` at (len-1)//page so the page holding the
        last token — whose logits must be recomputed — stays private."""
        toks = tokens if type(tokens) is list else [int(t) for t in tokens]
        n = len(toks) // self.page
        if max_pages is not None:
            n = min(n, max_pages)
        node, out = self.root, []
        for i in range(n):
            child = node.children.get(
                tuple(toks[i * self.page:(i + 1) * self.page]))
            if child is None:
                break
            out.append(child.page_id)
            node = child
        return out

    def register_tokens(self, tokens, page_ids: list[int]) -> int:
        """Index `page_ids[i]` under the i-th page chunk of `tokens` (full
        pages only). Existing nodes win — identical prefixes admitted
        without matching (prefix cache off mid-flight) keep one canonical
        page per chunk. Returns the number of newly indexed pages."""
        toks = tokens if type(tokens) is list else [int(t) for t in tokens]
        n = min(len(toks) // self.page, len(page_ids))
        node, new = self.root, 0
        for i in range(n):
            chunk = tuple(toks[i * self.page:(i + 1) * self.page])
            child = node.children.get(chunk)
            if child is None:
                pid = page_ids[i]
                if pid in self._node_of_page:
                    break                       # page canonical elsewhere
                child = _RadixNode(chunk, node, pid)
                node.children[chunk] = child
                self._node_of_page[pid] = child
                new += 1
            node = child
        return new

    def page_indexed(self, pid: int) -> bool:
        """Radix-tree membership replaces the base exact-chain index: a page
        with a radix node survives preemption (retired-LRU) and will be
        matched back on readmission, so its rows cost nothing to recompute."""
        return pid in self._node_of_page

    # -- preemption --------------------------------------------------------

    def preempt_release(self, slot: int, tokens) -> list[int]:
        """Evict a running slot: index its full written pages first (prompt
        AND generated rows — greedy decode makes the KV canonical for the
        token prefix), then release. Pages another slot still reads keep
        their refcount; the victim's own full pages land in the retired LRU
        so a prompt readmission can skip their recompute if the pool
        pressure passes before they are reclaimed."""
        if self.retain:
            self.register_tokens(tokens, self.pages[slot])
        return self.release(slot)

    # -- warm restart: snapshot / restore ----------------------------------

    def snapshot_kv(self, cache, ckpt_dir: str, step: int = 0) -> int:
        """Persist the radix index AND its page contents through the
        checkpoint store (atomic rename, crash-safe). Saved per node,
        parent-first: the token chunk, the parent's node index (-1 = child
        of root), and the node's LRU rank (-1 = active at snapshot time,
        else 0-based oldest-first position in the retired LRU). Page
        contents are gathered along the pool's page axis — for the packed
        int8 layout the codes and shared exponents round-trip bit-exactly,
        so a restored prefix is the SAME KV the donor engine computed.
        Returns the number of snapshotted pages."""
        import jax  # local: the manager is host-only except for snapshots
        from repro.checkpoint.store import save_checkpoint
        nodes: list[_RadixNode] = []
        stack = [self.root]
        while stack:                            # DFS, parents appended first
            node = stack.pop()
            if node is not self.root:
                nodes.append(node)
            stack.extend(node.children.values())
        idx = {id(n): i for i, n in enumerate(nodes)}
        rank = {pid: r for r, pid in enumerate(self._lru)}   # oldest first
        pids = [n.page_id for n in nodes]
        chunks = (np.asarray([n.chunk for n in nodes], np.int32)
                  if nodes else np.zeros((0, self.page), np.int32))

        def take(leaf):
            return np.take(np.asarray(jax.device_get(leaf)), pids, axis=1)

        pages = {g: jax.tree.map(take, cache[g])
                 for g in ("layers", "dense") if g in cache}
        tree = {
            "meta": {"page": np.int32(self.page), "n": np.int32(len(nodes))},
            "chunks": chunks,
            "parent": np.asarray(
                [-1 if n.parent is self.root else idx[id(n.parent)]
                 for n in nodes], np.int32),
            "lru_rank": np.asarray(
                [rank.get(n.page_id, -1) for n in nodes], np.int32),
            "pages": pages,
        }
        save_checkpoint(ckpt_dir, step, tree)
        return len(nodes)

    def restore_kv(self, cache, ckpt_dir: str, step: int | None = None):
        """Warm-start the prefix cache from ``snapshot_kv`` output:
        -> (cache', n_restored). Restored chains are rebuilt parent-first
        into the radix tree using FREE pages only (restore never evicts —
        a node that finds the free list empty is dropped along with its
        descendants), parked in the retired LRU in their saved recency
        order (actives-at-snapshot park at the MRU end), and their saved
        contents scattered into the pool along the page axis. Chunks the
        tree already indexes keep their existing canonical page. The first
        admission round after restore therefore sees prefix hits exactly
        as if the donor's requests had retired here."""
        import jax
        import jax.numpy as jnp
        from repro.checkpoint.store import load_checkpoint_arrays
        step, data = load_checkpoint_arrays(ckpt_dir, step)
        if data is None:
            return cache, 0
        assert int(data["meta/page"]) == self.page, \
            f"snapshot page size {int(data['meta/page'])} != {self.page}"
        n = int(data["meta/n"])
        chunks, parent = data["chunks"], data["parent"]
        lru_rank = data["lru_rank"]
        placed: list[_RadixNode | None] = [None] * n
        kept: list[tuple[int, int]] = []        # (saved node idx, page id)
        for i in range(n):
            par = self.root if parent[i] < 0 else placed[parent[i]]
            if par is None:
                continue                        # ancestor dropped
            chunk = tuple(int(t) for t in chunks[i])
            existing = par.children.get(chunk)
            if existing is not None:
                placed[i] = existing            # chunk already canonical
                continue
            if not self.free:
                continue                        # restore never evicts
            pid = self.free.pop()
            node = _RadixNode(chunk, par, pid)
            par.children[chunk] = node
            self._node_of_page[pid] = node      # refcount stays 0: retired
            placed[i] = node
            kept.append((i, pid))
        # Park in saved recency order: retired ranks ascending (oldest
        # first), then pages that were ACTIVE at snapshot time at MRU end.
        for i, pid in sorted(
                kept, key=lambda t: (int(lru_rank[t[0]]) < 0,
                                     int(lru_rank[t[0]]))):
            self._lru[pid] = placed[i]
        if kept:
            sel = np.asarray([i for i, _ in kept])
            dst = np.asarray([p for _, p in kept])
            cache = dict(cache)
            for g in ("layers", "dense"):
                if g not in cache:
                    continue
                leaves, treedef = jax.tree_util.tree_flatten_with_path(
                    cache[g])
                out = []
                for path, leaf in leaves:
                    key = "/".join(
                        ["pages", g] +
                        [str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path])
                    arr = jnp.asarray(data[key][:, sel], leaf.dtype)
                    out.append(leaf.at[:, dst].set(arr))
                cache[g] = jax.tree_util.tree_unflatten(treedef, out)
        self.restored_pages += len(kept)
        return cache, len(kept)
