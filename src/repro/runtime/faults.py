"""Shared fault-injection and health-monitoring primitives.

Hoisted out of ``runtime/resilient.py`` (which keeps re-exports) so the
TRAINING loop and the SERVING stack consume one set of chaos/health
building blocks instead of growing parallel copies:

  * ``FailureInjector`` — step-keyed chaos monkey for the training loop:
    raises ``InjectedFailure`` at configured steps, once each (stands in
    for preemption / device loss in CI).
  * ``StragglerMonitor`` — wall-time EWMA + variance; observations slower
    than mean + k*sigma are flagged. The training loop surfaces flags in
    metrics; the serving front door derives per-replica HEALTH from it
    (a replica whose engine ticks straggle is reported degraded).
  * ``ChaosInjector`` — the SERVING chaos hook. Deterministic and
    (seed, tick)-keyed so CI can exercise every serving failure path
    reproducibly:
      - fail_ticks: engine tick indices that raise ``InjectedFailure``
        ONCE each (retryable — the injection fires at the tick boundary,
        before any engine state mutates, so a supervised retry of the
        same tick is exact);
      - tick_fail_rate: seeded per-tick Bernoulli failures (same
        raise-once, boundary-injected semantics; the draw is keyed by
        (seed, tick), not by call order, so retries do not re-roll);
      - kill_at_tick: the tick at which the replica DIES —
        ``ReplicaKilled`` is fatal, never retried; the fleet router fails
        the replica's in-flight streams over to survivors;
      - stall_ticks: tick indices that sleep ``stall_s`` before the
        engine advances (models a stalled stream / slow device without
        failing anything — exercises timeout and straggler paths);
      - poison_rids: request ids whose stream is failed with the cause
        at its first token event — failure ISOLATION: only the poisoned
        stream errors, the server keeps ticking.

Everything here is host-side and jax-free.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field


class InjectedFailure(RuntimeError):
    """A deterministic injected fault (retryable at the tick boundary)."""


class ReplicaKilled(InjectedFailure):
    """Fatal injected fault: the serving replica is dead. Never retried —
    the engine loop stops, open streams fail with this cause, and a fleet
    fails the work over to a surviving replica."""


@dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclass
class StragglerMonitor:
    alpha: float = 0.2
    k_sigma: float = 3.0
    warmup: int = 5
    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._mean = dt if self._n == 1 else (self._mean + dt) / 2
            return False
        d = dt - self._mean
        is_straggler = d > self.k_sigma * max(self._var, 1e-12) ** 0.5 and self._n > self.warmup
        self._mean += self.alpha * d
        self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        if is_straggler:
            self.flagged.append((step, dt))
        return is_straggler

    @property
    def mean_s(self) -> float:
        """Current EWMA of the observed wall time (0.0 before warmup) —
        the serving layer's projected-latency input for deadline-aware
        load shedding."""
        return self._mean if self._n else 0.0


@dataclass
class ChaosInjector:
    """Deterministic serving chaos, keyed by (seed, tick) and request id.

    ``on_tick(tick)`` is called at the START of every engine tick, before
    any engine state mutates — so a raise here is retry-exact: re-running
    the tick re-enters ``on_tick`` with the same tick number, the
    raise-once bookkeeping skips, and the engine advances as if the fault
    never happened. A real mid-tick device failure has no such guarantee;
    the supervised retry is best-effort there and bounded either way.
    """
    seed: int = 0
    fail_ticks: tuple = ()            # retryable one-shot tick failures
    tick_fail_rate: float = 0.0       # seeded Bernoulli per-tick failures
    kill_at_tick: int | None = None   # fatal: the replica dies here
    stall_ticks: tuple = ()           # ticks delayed by stall_s (no error)
    stall_s: float = 0.05
    poison_rids: tuple = ()           # rids failed at their first token
    injected_failures: int = 0
    killed: bool = False
    _fired: set = field(default_factory=set)

    def _draw(self, tick: int) -> float:
        # keyed by (seed, tick), NOT by call order: a retried tick sees
        # the same draw it already survived-or-failed, never a fresh roll
        return random.Random(f"chaos:{self.seed}:{tick}").random()

    def on_tick(self, tick: int):
        """Raise/stall per the configured schedule. Called at the tick
        boundary (engine state untouched)."""
        if self.kill_at_tick is not None and tick >= self.kill_at_tick \
                and not self.killed:
            self.killed = True
            raise ReplicaKilled(f"injected replica kill at tick {tick}")
        if tick in self.fail_ticks and ("fail", tick) not in self._fired:
            self._fired.add(("fail", tick))
            self.injected_failures += 1
            raise InjectedFailure(f"injected tick failure at tick {tick}")
        if self.tick_fail_rate > 0.0 and ("rate", tick) not in self._fired \
                and self._draw(tick) < self.tick_fail_rate:
            self._fired.add(("rate", tick))
            self.injected_failures += 1
            raise InjectedFailure(f"injected seeded failure at tick {tick}")
        if tick in self.stall_ticks and ("stall", tick) not in self._fired:
            self._fired.add(("stall", tick))
            time.sleep(self.stall_s)

    def is_poisoned(self, rid: int) -> bool:
        return rid in self.poison_rids
