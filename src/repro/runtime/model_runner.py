"""ModelRunner: compiled-execution layer of the serving engine.

One of the three engine layers (Scheduler / KVCacheManager / ModelRunner —
see runtime/__init__.py for the contract). The runner owns the params, the
QuantConfig, and EVERY compiled entry point of the serving path, so the
other layers stay pure host Python:

  * ``make_decode()`` — the one jitted decode step per tick (KV donated so
    XLA aliases the pool instead of double-buffering it). The decode is
    SPLIT INTO DISPATCH/COLLECT HALVES for the overlapped engine loop:
    ``decode_dispatch`` launches the jitted step and returns device
    futures immediately (jax dispatch is asynchronous on every backend),
    so the host can run the NEXT tick's admission policy — scheduling,
    radix matching, block-table arithmetic, prefill staging — while the
    device crunches; ``decode_collect`` is the only place the engine
    blocks (``jax.block_until_ready`` at the stream edge), turning the
    logits future into host-side token ids;
  * ``dense_prefill`` — the dense-layout reference path: prompt padded to a
    power-of-two BUCKET, one compilation per bucket (O(log max_len) ladder);
  * ``batched_chunk_prefill`` — BATCHED MULTI-SLOT incremental chunked
    prefill over the paged cache: ONE compiled shape
    ``(prefill_slots, prefill_chunk)`` prefills a chunk for up to
    `prefill_slots` admissions per step instead of looping requests
    sequentially. Jobs run in LOCKSTEP on the absolute-offset grid: job j's
    chunk k executes at step ``ceil(start_j/chunk) + k``, which guarantees
    that by the time a prefix-sharing follower computes queries at
    positions >= its shared region, the leader (same batch or already
    resident) has scattered every shared row — per layer the scatter of all
    batch rows lands before the gather, so same-step producer rows are
    visible too, and the schedule is race-free for any chunk/page-size
    combination. Idle batch rows carry a sentinel block-table row (writes
    dropped, reads masked) and their outputs are discarded, so a partial
    burst costs one padded call, not a retrace.

Counters: ``prefill_traces`` (distinct compiled prefill shapes — the
batched chunk path contributes exactly ONE), ``chunk_prefill_calls``
(per-request chunk work items, so prefix hits stay measurable as skipped
chunks), ``prefill_steps`` (batched lockstep steps actually launched —
the wall-clock admission cost; < chunk_prefill_calls whenever a burst
actually batched).
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models import partitioning as PT


class ModelRunner:
    def __init__(self, cfg, params, qcfg, *, prefill_chunk: int = 32,
                 prefill_slots: int = 4, min_prefill_bucket: int = 16,
                 mesh=None, paged_attn: str = "unfused"):
        assert paged_attn in ("fused", "unfused"), paged_attn
        self.cfg, self.qcfg = cfg, qcfg
        self.mesh = mesh
        # "fused" routes packed paged decode/chunk-prefill attention through
        # the Pallas kernel (kernels/paged_attention.py); baked into the
        # jitted closures below, so it is a per-runner compile-time choice.
        # With a mesh, the fused path runs per page-pool shard inside a
        # shard_map over the "model" axis (flash-decoding sequence
        # parallelism) — params stay TP-sharded via the same mesh, while
        # the jnp path head-shards the KV pools instead
        self.paged_attn = paged_attn
        self._params_src = params       # pre-sharding identity (facade assert)
        if mesh is not None:
            # serve-mode TP: weights sharded over "model" via the training
            # stack's path->spec rules; committed device_put means every
            # jitted entry point below compiles as an SPMD program without
            # per-call in_shardings plumbing (GSPMD propagates from operands)
            from repro.launch.sharding import param_shardings
            shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
            params = jax.device_put(
                params, param_shardings(shapes, mesh, "serve"))
        self.params = params
        self.prefill_chunk = max(1, prefill_chunk)
        self.prefill_slots = max(1, prefill_slots)
        self.min_bucket = max(1, min_prefill_bucket)
        self._prefill_fns: dict[int, object] = {}   # bucket -> jitted prefill
        self._chunk_prefill_fn = None   # the ONE batched chunk-prefill shape
        self._decode_fn = None          # cached jitted decode (shared facades)
        self._decode_wrapped = None     # ctx-entering wrapper around it
        self.prefill_traces = 0         # distinct prefill shapes compiled
        self.chunk_prefill_calls = 0    # per-request chunk work items
        self.prefill_steps = 0          # batched lockstep steps launched

    def _ctx(self):
        """Activation-sharding context every compiled call runs under: binds
        SERVE_RULES so ``partitioning.constrain`` calls inside the model
        resolve against this mesh (a no-op when the runner has no mesh)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return PT.activation_sharding(self.mesh, PT.SERVE_RULES)

    # -- decode ------------------------------------------------------------

    def make_decode(self):
        """The jitted decode step. The pre-call cache is never touched
        after a tick: donate it so XLA aliases the new pool onto the old
        instead of double-buffering the whole KV store every decode. The
        jit object is cached so façades sharing one runner (several
        batchers, a bench sweeping configurations) reuse the compiled
        executable instead of retracing per façade."""
        if self._decode_fn is None:
            cfg, qcfg, pa = self.cfg, self.qcfg, self.paged_attn
            self._decode_fn = jax.jit(
                lambda p, c, t: M.decode_step(p, cfg, c, t, qcfg, pa),
                donate_argnums=(1,))
        if self._decode_wrapped is None:
            fn = self._decode_fn

            def decode(p, c, t):
                with self._ctx():
                    return fn(p, c, t)

            self._decode_wrapped = decode
        return self._decode_wrapped

    def decode_dispatch(self, cache, cur_tok):
        """DISPATCH half of the decode tick: launch the jitted step and
        return ``(logits, new_cache)`` as device futures WITHOUT blocking.
        jax dispatches asynchronously, so between this call and
        ``decode_collect`` the host is free to run the next tick's
        scheduling/admission work while the device executes."""
        return self.make_decode()(self.params, cache, cur_tok)

    def decode_collect(self, logits) -> np.ndarray:
        """COLLECT half: the stream edge. The ONLY blocking point of the
        overlapped engine loop — ``block_until_ready`` on the in-flight
        logits, then the greedy argmax as host token ids (B,)."""
        logits = jax.block_until_ready(logits)
        return np.asarray(jax.device_get(jnp.argmax(logits, axis=-1)))

    # -- dense-layout bucketed prefill (reference path) --------------------

    def bucket(self, p_len: int) -> int:
        """Dense-layout prompt staging length: next power of two >= p_len
        (floored at min_bucket) — an O(log max_len) shape ladder."""
        return max(self.min_bucket, 1 << max(p_len - 1, 0).bit_length())

    def dense_prefill(self, prompt: jnp.ndarray):
        """Pad the prompt to its bucket, run one jitted forward per BUCKET
        (not per length), read logits at row p_len-1 (the padded tail is
        causally invisible to real rows). Returns (next-token logits (V,),
        staged cache of bucket rows)."""
        p_len = prompt.shape[0]
        bkt = self.bucket(p_len)
        fn = self._prefill_fns.get(bkt)
        if fn is None:
            mod = M.family_module(self.cfg)
            cfg, qcfg = self.cfg, self.qcfg

            def run(params, toks):
                logits, cache, _ = mod.forward(
                    params, cfg, toks, qcfg,
                    cache=mod.init_cache(cfg, 1, toks.shape[1]))
                return logits, cache

            fn = jax.jit(run)
            self._prefill_fns[bkt] = fn
            self.prefill_traces += 1
        toks = jnp.pad(prompt.astype(jnp.int32), (0, bkt - p_len))[None, :]
        with self._ctx():
            logits, staged = fn(self.params, toks)
        return logits[0, p_len - 1], staged

    # -- batched multi-slot chunked prefill (paged layout) -----------------

    def _chunk_fn(self):
        """The single jitted batched chunk-prefill step: (params,
        {layers[,dense]}, block-table rows (P, max_pages), pos (P,),
        (P, prefill_chunk) tokens) -> (logits (P, chunk, V), new KV).
        ONE shape for every prompt length AND burst size <= P — compare
        the dense ladder's O(log max_len)."""
        if self._chunk_prefill_fn is None:
            cfg, qcfg, pa = self.cfg, self.qcfg, self.paged_attn
            mod = M.family_module(cfg)

            def run(params, kv, bt_rows, pos, toks):
                sub = {**kv, "block_table": bt_rows, "pos": pos}
                logits, new_cache = mod.chunk_prefill(params, cfg, sub, toks,
                                                      qcfg, pa)
                return logits, {k: v for k, v in new_cache.items()
                                if k in ("layers", "dense")}

            # donate the KV pool (arg 1 holds only the pool leaves — the
            # table rows and pos pass through undonated): step i+1's pool
            # aliases step i's instead of double-buffering the store
            self._chunk_prefill_fn = jax.jit(run, donate_argnums=(1,))
            self.prefill_traces += 1
        return self._chunk_prefill_fn

    def batched_chunk_prefill(self, cache, jobs, sentinel: int):
        """Prefill every job — (slot, tokens (n,) int32, start_row,
        depends) — into its pages through `cache`'s block table, batching
        up to `prefill_slots` jobs per compiled step. Returns (new cache,
        {slot: last REAL row's logits (V,)}).

        `depends=True` marks a job whose shared-prefix pages are WRITTEN by
        another job of the same admission round: it enters the lockstep
        schedule at ``ceil(start/chunk)`` so its producers stay ahead. A
        job whose prefix is already resident (earlier round, radix LRU)
        starts at step 0. Jobs beyond `prefill_slots` run as additional
        full groups (a later group may freely read pages a finished
        earlier group wrote). Tail chunks pad to the chunk width; pad rows
        scatter past the prompt inside the slot's own reservation, stay
        position-masked, and decode overwrites them."""
        chunk, P = self.prefill_chunk, self.prefill_slots
        fn = self._chunk_fn()
        finals: dict[int, jnp.ndarray] = {}
        for g in range(0, len(jobs), P):
            group = jobs[g:g + P]
            t_act = [-(-start // chunk) if dep else 0
                     for (_, _, start, dep) in group]
            n_chunks = [-(-(len(toks) - start) // chunk)
                        for (_, toks, start, _) in group]
            for t in range(max(ta + nc for ta, nc in zip(t_act, n_chunks))):
                tok_blk = np.zeros((P, chunk), np.int32)
                pos = np.zeros((P,), np.int32)
                slot_of = np.zeros((P,), np.int32)
                active = np.zeros((P,), bool)
                last: dict[int, tuple[int, int]] = {}
                for j, (slot, toks, start, _) in enumerate(group):
                    k = t - t_act[j]
                    if k < 0 or k >= n_chunks[j]:
                        continue
                    off = start + k * chunk
                    real = min(chunk, len(toks) - off)
                    tok_blk[j, :real] = toks[off:off + real]
                    pos[j], slot_of[j], active[j] = off, slot, True
                    if k == n_chunks[j] - 1:
                        last[j] = (slot, real - 1)
                if not active.any():
                    continue            # a hole in the lockstep schedule
                # idle rows read a sentinel table row: writes dropped, the
                # garbage gather masked by pos, outputs discarded below
                bt_rows = jnp.where(jnp.asarray(active)[:, None],
                                    cache["block_table"][jnp.asarray(slot_of)],
                                    sentinel)
                kv = {"layers": cache["layers"]}
                if "dense" in cache:
                    kv["dense"] = cache["dense"]
                with self._ctx():
                    logits, new_kv = fn(self.params, kv, bt_rows,
                                        jnp.asarray(pos), jnp.asarray(tok_blk))
                cache = {**cache, **new_kv}
                self.chunk_prefill_calls += int(active.sum())
                self.prefill_steps += 1
                for j, (slot, r) in last.items():
                    finals[slot] = logits[j, r]
        return cache, finals
