"""Algorithm 1 — selection of the overlap bit width.

score[o] = w * Overhead_norm[o] + (1 - w) * PPL_norm[o];  pick argmin.

`ppl_fn(fmt)` is injected (benchmarks use the tiny-LM PPL; tests use an MSE
proxy) so the algorithm itself is exactly the paper's.  Overhead model: the
stored bits per element (Table I equivalent bit-width) times a multiplier for
the wider integer path when the folded mantissa exceeds int8 (the TPU analogue
of the paper's wider multipliers/adders).
"""
from __future__ import annotations

from typing import Callable, Sequence

from repro.core import bbfp as B


def overhead(fmt: B.QuantFormat) -> float:
    """Relative hardware/compute cost of a BBFP(m,o) MAC on TPU: memory bits
    per element plus an accumulation-width penalty when the folded integer
    leaves the int8 MXU path."""
    bits = B.equivalent_bit_width(fmt)
    fold = B.folded_max(fmt)
    acc_penalty = 1.0 if fold <= 127 else (2.0 if fold <= 32767 else 4.0)
    return bits * acc_penalty


def select_overlap_width(ppl_fn: Callable[[B.QuantFormat], float],
                         mantissa: int,
                         w: float = 0.5,
                         candidates: Sequence[int] | None = None) -> tuple[int, dict]:
    """Algorithm 1. Returns (best_o, diagnostics)."""
    cand = list(candidates) if candidates is not None else list(range(0, mantissa))
    fmts = [B.QuantFormat("bbfp", mantissa, o) for o in cand]
    ppl = [float(ppl_fn(f)) for f in fmts]
    ovh = [overhead(f) for f in fmts]
    ppl_max, ovh_max = max(ppl), max(ovh)
    scores = [w * (ov / ovh_max) + (1 - w) * (p / ppl_max) for p, ov in zip(ppl, ovh)]
    best = min(range(len(cand)), key=lambda i: scores[i])
    return cand[best], {"o": cand, "ppl": ppl, "overhead": ovh, "score": scores}
