"""Bidirectional Block Floating Point (BBFP) — the paper's core data format.

Implements, in pure JAX:

  * plain BFP quantisation (block shares the *max* exponent; Eq. 2),
  * BBFP quantisation (shared exponent = max - (m - o), per-element 1-bit flag
    selecting a high/low mantissa window; Eqs. 4-6 and 9),
  * dequantisation / fake-quant (round-trip) for both,
  * integer decomposition used by the Pallas matmul kernel: each block is
    (int mantissa with the flag folded in) x (power-of-two per-block scale).

Numerical convention
--------------------
For an element x with exponent e = floor(log2 |x|):

  BFP(k)       : E = max_e,           step = 2^(E - k + 1),           q = round(|x|/step)
  BBFP(m, o)   : E_s = max_e - (m-o)
                 flag = e > E_s
                 step = 2^(E_s - m + 1) * (2^(m-o) if flag else 1)
                 q    = clip(round(|x|/step), 0, 2^m - 1)

so the high window (flag=1) has exactly the precision plain BFP(m) would give
the outliers (step 2^(E_s - o + 1) = 2^(max_e - m + 1)), while the low window
gains (m-o) bits for the bulk of the values.  This is the arithmetic
equivalent of the paper's bit-window shift/truncate description (Eq. 4).

The *stored* form per block of N values is
  shared_exp  : int32 (one per block)
  mantissa    : uint  m bits  (one per element)
  flag, sign  : 1 bit each    (one per element)
giving the equivalent bit-widths of Table I:  (1+1+m) + (5+o?)/N ... see
``equivalent_bit_width``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

# Fixed by the paper: 5-bit shared exponent in all configurations.
SHARED_EXPONENT_BITS = 5
DEFAULT_BLOCK = 32  # paper's BlockSize (Table I); also the TPU VPU lane width.

_EXP_MIN = -(2 ** (SHARED_EXPONENT_BITS - 1))      # -16
_EXP_MAX = 2 ** (SHARED_EXPONENT_BITS - 1) - 1     # +15


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """A block-format descriptor. kind: 'bfp' | 'bbfp' | 'int' | 'none'."""
    kind: Literal["bfp", "bbfp", "int", "none"]
    mantissa: int = 4          # m  (stored mantissa bits, unsigned; sign separate)
    overlap: int = 2           # o  (bbfp only)
    block: int = DEFAULT_BLOCK
    # shared-exponent strategy offset relative to Eq. 9. 0 = paper's max-(m-o).
    # +1 = "max-1" strategy of Fig. 3, -1 = "max-3" strategy. bfp ignores it.
    exponent_offset: int = 0

    def __post_init__(self):
        if self.kind == "bbfp" and not (0 <= self.overlap <= self.mantissa):
            raise ValueError(f"overlap must be in [0, m]; got {self}")

    @property
    def name(self) -> str:
        if self.kind == "bbfp":
            return f"BBFP({self.mantissa},{self.overlap})"
        if self.kind == "bfp":
            return f"BFP{self.mantissa}"
        if self.kind == "int":
            return f"INT{self.mantissa}"
        return "FP"

    @property
    def shift(self) -> int:
        """m - o: the flag=1 left-shift amount (Eq. 6's log2 f)."""
        return self.mantissa - self.overlap


# Formats used throughout the paper's tables.
FP_NONE = QuantFormat("none")
BFP4 = QuantFormat("bfp", 4)
BFP6 = QuantFormat("bfp", 6)
BFP8 = QuantFormat("bfp", 8)
BFP10 = QuantFormat("bfp", 10)
BBFP21 = QuantFormat("bbfp", 2, 1)
BBFP31 = QuantFormat("bbfp", 3, 1)
BBFP32 = QuantFormat("bbfp", 3, 2)
BBFP42 = QuantFormat("bbfp", 4, 2)
BBFP43 = QuantFormat("bbfp", 4, 3)
BBFP63 = QuantFormat("bbfp", 6, 3)
BBFP64 = QuantFormat("bbfp", 6, 4)
BBFP65 = QuantFormat("bbfp", 6, 5)
BBFP105 = QuantFormat("bbfp", 10, 5)
INT8 = QuantFormat("int", 8)

FORMATS = {
    f.name: f
    for f in [FP_NONE, BFP4, BFP6, BFP8, BFP10, BBFP21, BBFP31, BBFP32, BBFP42,
              BBFP43, BBFP63, BBFP64, BBFP65, BBFP105, INT8]
}


def parse_format(spec: str) -> QuantFormat:
    """'BBFP(4,2)' | 'bbfp4_2' | 'BFP6' | 'int8' | 'none' -> QuantFormat."""
    s = spec.strip()
    if s in FORMATS:
        return FORMATS[s]
    low = s.lower().replace(" ", "")
    if low in ("none", "fp", "fp16", "fp32", "bf16"):
        return FP_NONE
    if low.startswith("bbfp"):
        body = low[4:].strip("()_").replace("_", ",")
        m, o = (int(v) for v in body.split(","))
        return QuantFormat("bbfp", m, o)
    if low.startswith("bfp"):
        return QuantFormat("bfp", int(low[3:]))
    if low.startswith("int"):
        return QuantFormat("int", int(low[3:]))
    raise ValueError(f"unknown quant format {spec!r}")


# ---------------------------------------------------------------------------
# exponent helpers
# ---------------------------------------------------------------------------

def _exponent(x: jax.Array) -> jax.Array:
    """floor(log2 |x|) as int32; zeros map to _EXP_MIN (so they never drive
    the block max). Clipped into the 5-bit shared-exponent range.

    Edge-case contract (shared with the Pallas kernel's raw-bias bit trick,
    ``kernels.bbfp_matmul._exponent_tile``; parity-tested):
      * zeros (±0)            -> _EXP_MIN  (never drive the block max)
      * subnormals            -> _EXP_MIN  (true exponent <= -127, clipped)
      * |x| >= 2^15           -> _EXP_MAX  (5-bit shared-exponent saturation)
      * inf / nan             -> _EXP_MAX  (the bit trick reads the all-ones
        exponent field as 128 and clips; frexp instead returns e=0, so the
        non-finite case must be pinned explicitly here)
    """
    ax = jnp.abs(x).astype(jnp.float32)
    # frexp: x = f * 2^e with f in [0.5, 1)  =>  floor(log2|x|) = e - 1
    _, e = jnp.frexp(ax)
    e = (e - 1).astype(jnp.int32)
    e = jnp.where(ax == 0, _EXP_MIN, e)
    e = jnp.where(jnp.isfinite(ax), e, _EXP_MAX)
    return jnp.clip(e, _EXP_MIN, _EXP_MAX)


def _to_blocks(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    """Reshape last dim into (n_blocks, block), zero-padding if needed.
    Returns (blocked, pad)."""
    *lead, n = x.shape
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (len(lead)) + [(0, pad)])
    return x.reshape(*lead, (n + pad) // block, block), pad


def _from_blocks(xb: jax.Array, pad: int) -> jax.Array:
    *lead, nb, b = xb.shape
    x = xb.reshape(*lead, nb * b)
    if pad:
        x = x[..., : nb * b - pad]
    return x


# ---------------------------------------------------------------------------
# quantise / dequantise
# ---------------------------------------------------------------------------

def shared_exponent(x_blocked: jax.Array, fmt: QuantFormat) -> jax.Array:
    """Per-block shared exponent. BFP: block max. BBFP: Eq. 9 (+offset)."""
    e = _exponent(x_blocked)
    e_max = jnp.max(e, axis=-1)
    if fmt.kind == "bfp":
        return e_max
    if fmt.kind == "bbfp":
        return jnp.clip(e_max - fmt.shift + fmt.exponent_offset, _EXP_MIN, _EXP_MAX)
    raise ValueError(fmt.kind)


def quantize_blocked(x_blocked: jax.Array, fmt: QuantFormat):
    """Quantise an already-blocked array (..., n_blocks, block).

    Returns dict with:
      mantissa : int32  (unsigned value, 0..2^m-1)
      sign     : int32  (+1/-1)
      flag     : int32  (0/1; always 0 for bfp)
      exp      : int32  per-block shared exponent  (..., n_blocks)
    """
    x = x_blocked.astype(jnp.float32)
    m = fmt.mantissa
    if fmt.kind == "int":
        # symmetric per-block int quantisation (absmax scale) — the INT8 baseline.
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = jnp.where(amax == 0, 1.0, amax / (2 ** (m - 1) - 1))
        q = jnp.clip(jnp.round(x / scale), -(2 ** (m - 1) - 1), 2 ** (m - 1) - 1)
        return {
            "mantissa": jnp.abs(q).astype(jnp.int32),
            "sign": jnp.where(q < 0, -1, 1).astype(jnp.int32),
            "flag": jnp.zeros_like(q, jnp.int32),
            "exp": scale[..., 0],  # float scale stored in 'exp' slot for int kind
        }

    e_s = shared_exponent(x, fmt)                      # (..., nb)
    e = _exponent(x)
    if fmt.kind == "bfp":
        flag = jnp.zeros_like(e)
        step_log2 = e_s[..., None] - m + 1
    else:
        flag = (e > e_s[..., None]).astype(jnp.int32)
        step_log2 = e_s[..., None] - m + 1 + flag * fmt.shift
    step = jnp.exp2(step_log2.astype(jnp.float32))
    q = jnp.round(jnp.abs(x) / step)
    q = jnp.clip(q, 0, 2**m - 1)
    sign = jnp.where(jnp.signbit(x), -1, 1).astype(jnp.int32)
    return {
        "mantissa": q.astype(jnp.int32),
        "sign": sign,
        "flag": flag.astype(jnp.int32),
        "exp": e_s,
    }


def dequantize_blocked(qdict, fmt: QuantFormat) -> jax.Array:
    m = fmt.mantissa
    if fmt.kind == "int":
        scale = qdict["exp"][..., None]
        return (qdict["sign"] * qdict["mantissa"]).astype(jnp.float32) * scale
    step_log2 = qdict["exp"][..., None] - m + 1
    if fmt.kind == "bbfp":
        step_log2 = step_log2 + qdict["flag"] * fmt.shift
    step = jnp.exp2(step_log2.astype(jnp.float32))
    return qdict["sign"] * qdict["mantissa"].astype(jnp.float32) * step


def fake_quant(x: jax.Array, fmt: QuantFormat, axis: int = -1) -> jax.Array:
    """Round-trip quantise along `axis` (blocked). Identity for kind='none'.
    Straight-through gradient (the QAT path)."""
    if fmt.kind == "none":
        return x
    x_ = jnp.moveaxis(x, axis, -1)
    xb, pad = _to_blocks(x_, fmt.block)
    y = dequantize_blocked(quantize_blocked(xb, fmt), fmt)
    y = _from_blocks(y, pad)
    y = jnp.moveaxis(y, -1, axis)
    # straight-through estimator: forward quantised, backward identity.
    zero = x - jax.lax.stop_gradient(x)
    return zero + jax.lax.stop_gradient(y.astype(x.dtype))


def quantize(x: jax.Array, fmt: QuantFormat, axis: int = -1):
    """Quantise along axis; returns (qdict, pad). Blocked layout (..., nb, B)."""
    x_ = jnp.moveaxis(x, axis, -1)
    xb, pad = _to_blocks(x_, fmt.block)
    return quantize_blocked(xb, fmt), pad


def dequantize(qdict, fmt: QuantFormat, pad: int = 0, axis: int = -1) -> jax.Array:
    y = _from_blocks(dequantize_blocked(qdict, fmt), pad)
    return jnp.moveaxis(y, -1, axis)


# ---------------------------------------------------------------------------
# integer decomposition for the MXU matmul kernel
# ---------------------------------------------------------------------------

def to_int_repr(x: jax.Array, fmt: QuantFormat):
    """Decompose x (blocked along last dim) into (q_int, scale):
         x ≈ q_int * scale[..., None]
    with q_int = sign * mantissa * 2^(shift*flag)  — the flag folded in, so a
    plain integer dot over the block reproduces Eq. 7/10. For BBFP(m,o) the
    folded magnitude is < 2^(2m-o), i.e. int8-safe for m=4,o=2 (<=60) and
    m=3 (<=28); int16 for m=6,o=3 (<=504)."""
    qd, _pad = quantize(x, fmt, axis=-1)
    if fmt.kind == "int":
        q = qd["sign"] * qd["mantissa"]
        return q, qd["exp"]
    fold = qd["mantissa"] << (qd["flag"] * fmt.shift) if fmt.kind == "bbfp" else qd["mantissa"]
    q = qd["sign"] * fold
    scale = jnp.exp2((qd["exp"] - fmt.mantissa + 1).astype(jnp.float32))
    return q, scale


def folded_max(fmt: QuantFormat) -> int:
    """Max |q_int| after flag folding — decides int8 vs wider accumulation."""
    if fmt.kind == "bbfp":
        return (2**fmt.mantissa - 1) << fmt.shift
    if fmt.kind == "int":
        # symmetric int: mantissa clips at 2^(m-1)-1 (INT8 -> 127, int8-safe)
        return 2 ** (fmt.mantissa - 1) - 1
    return 2**fmt.mantissa - 1


# ---------------------------------------------------------------------------
# packed weight storage (serving): int8 folded mantissas + per-block scales
# ---------------------------------------------------------------------------

def pack_weight(w: jax.Array, fmt: QuantFormat, cast_dtype=jnp.bfloat16):
    """Offline weight packing for serving. w: (..., K, N), blocks along K
    (the contraction dim, K % 32 == 0). Returns
       {"q": int8/int16 (..., K, N), "scale": f32 (..., K/32, N)}
    with  unpack_weight(pack_weight(w)) == fake_quant(w.astype(cast_dtype),
    axis=-2)  exactly (the runtime fake-quant path sees bf16-cast weights,
    so packing mirrors that cast). Storage is 8 bits/elt + one scale per 32
    — Table I's memory-efficiency claim made real in the serving HLO."""
    *lead, k, n = w.shape
    assert k % DEFAULT_BLOCK == 0, (w.shape,)
    if cast_dtype is not None:
        w = w.astype(cast_dtype)
    w2 = jnp.swapaxes(w, -2, -1)                    # (..., N, K)
    qd, pad = quantize(w2, fmt, axis=-1)            # blocked along K
    assert pad == 0
    if fmt.kind == "bbfp":
        fold = qd["mantissa"] << (qd["flag"] * fmt.shift)
    else:
        fold = qd["mantissa"]
    q2 = qd["sign"] * fold                          # (..., N, nb, 32)
    q = jnp.swapaxes(q2.reshape(*lead, n, k), -2, -1)
    if fmt.kind == "int":
        # int kind stores the float absmax scale directly in the 'exp' slot
        scale2 = qd["exp"].astype(jnp.float32)
    else:
        scale2 = jnp.exp2((qd["exp"] - fmt.mantissa + 1).astype(jnp.float32))
    scale = jnp.swapaxes(scale2, -2, -1)            # (..., nb, N)
    dtype = jnp.int8 if folded_max(fmt) <= 127 else jnp.int16
    return {"q": q.astype(dtype), "scale": scale}


def unpack_weight(packed: dict, out_dtype=jnp.bfloat16) -> jax.Array:
    """Dequantise a packed weight: one multiply per element (fusable)."""
    q, scale = packed["q"], packed["scale"]
    *lead, k, n = q.shape
    nb = scale.shape[-2]
    qb = q.astype(jnp.float32).reshape(*lead, nb, k // nb, n)
    w = qb * scale[..., :, None, :]
    return w.reshape(*lead, k, n).astype(out_dtype)


# ---------------------------------------------------------------------------
# packed KV storage (serving): int8 codes + int8 per-block shared exponents
# ---------------------------------------------------------------------------

def kv_packable(fmt: QuantFormat) -> bool:
    """True when `fmt` fits the 8-bit KV page code: sign + flag + mantissa in
    one int8. bbfp needs m+1 magnitude bits (mantissa | flag<<m <= 2^(m+1)-1),
    bfp/int need m. BBFP(6,3) — the serving KV default — is exactly 8 bits."""
    if fmt.kind == "bbfp":
        return fmt.mantissa <= 6
    if fmt.kind == "bfp":
        return fmt.mantissa <= 7
    return False          # int kind carries a float scale, not an exponent


def pack_kv(x: jax.Array, fmt: QuantFormat):
    """Encode x (blocks along the LAST axis) into the KV page storage form:

       q   : int8, same shape as x — sign * (mantissa | flag << m), i.e. the
             paper's 1+1+m bit element (Table I) in one byte;
       exp : int8 (..., ceil(n/32)) — the 5-bit per-block shared exponent.

    8 + 8/32 = 8.25 bits/elt as stored (vs Table I's ideal 8.16 for
    BBFP(6,3): the exponent byte wastes 3 bits to stay addressable).
    EXACT round-trip for values already on the fmt grid (e.g. a bf16 cache
    written through ``quant.linear.qkv_cache``): requantisation preserves the
    block max exponent, every flag, and every mantissa, so
    unpack_kv(pack_kv(fake_quant(x))) == fake_quant(x) bitwise (tested)."""
    assert kv_packable(fmt), f"{fmt.name} does not fit int8 KV codes"
    qd, pad = quantize(x, fmt, axis=-1)
    code = qd["sign"] * (qd["mantissa"] | (qd["flag"] << fmt.mantissa))
    return {"q": _from_blocks(code, pad).astype(jnp.int8),
            "exp": qd["exp"].astype(jnp.int8)}


def unpack_kv(packed: dict, fmt: QuantFormat, out_dtype=jnp.bfloat16) -> jax.Array:
    """Decode pack_kv storage back to values (one shift/mask + one multiply
    per element — fusable into the attention gather)."""
    m = fmt.mantissa
    shift = fmt.shift if fmt.kind == "bbfp" else 0
    cb, pad = _to_blocks(packed["q"].astype(jnp.int32), fmt.block)
    mag = jnp.abs(cb)
    mant = mag & (2**m - 1)
    flag = mag >> m
    step_log2 = packed["exp"].astype(jnp.int32)[..., None] - m + 1 + flag * shift
    v = jnp.where(cb < 0, -mant, mant).astype(jnp.float32) \
        * jnp.exp2(step_log2.astype(jnp.float32))
    return _from_blocks(v, pad).astype(out_dtype)


# ---------------------------------------------------------------------------
# sub-byte packed KV storage: two nibble codes per byte (~4.25 bits/elt)
# ---------------------------------------------------------------------------

def kv_packable4(fmt: QuantFormat) -> bool:
    """True when `fmt`'s element code (sign + flag + mantissa) fits one
    NIBBLE. A bidirectional code needs 2 + m bits, so the widest 4-bit
    member of the family is BBFP(2,1) — BBFP(3,x) is a 5-bit code
    (1 sign + 1 flag + 3 mantissa) and cannot nibble-pack without dropping
    its flag, at which point it IS BFP3. Unidirectional BFP fits up to m=3."""
    if fmt.kind == "bbfp":
        return fmt.mantissa <= 2
    if fmt.kind == "bfp":
        return fmt.mantissa <= 3
    return False          # int kind carries a float scale, not an exponent


def pack_kv_nibble(x: jax.Array, fmt: QuantFormat):
    """Encode x (blocks along the LAST axis, even length) into the sub-byte
    KV page storage form — two sign-magnitude nibble codes per byte:

       q   : int8 (..., n/2) — element 2i in the low nibble, 2i+1 in the
             high nibble; each nibble is sign<<3 | (mantissa | flag<<m);
       exp : int8 (..., ceil(n/32)) — the 5-bit per-block shared exponent.

    4 + 8/32 = 4.25 bits/elt as stored (~4.16 ideal with a 5-bit exponent
    field) vs 16 for a bf16 cache — a 0.27x byte ratio. Same EXACT
    round-trip contract as ``pack_kv`` for values already on the fmt grid:
    unpack_kv_nibble(pack_kv_nibble(x)) == fake_quant(x) bitwise, and the
    decoded VALUES are stable under further pack/unpack cycles (codes are
    not canonical — a flag=1 mantissa re-encodes into the overlap window of
    the low one where both represent the same value — bytes at rest only
    move through snapshot/restore, which copies them verbatim; tested)."""
    assert kv_packable4(fmt), f"{fmt.name} does not fit nibble KV codes"
    assert x.shape[-1] % 2 == 0, f"nibble packing needs an even last dim: {x.shape}"
    qd, pad = quantize(x, fmt, axis=-1)
    mag = qd["mantissa"] | (qd["flag"] << fmt.mantissa)         # <= 7 (3 bits)
    nib = jnp.where(qd["sign"] < 0, mag | 8, mag)               # sign-magnitude
    nib = _from_blocks(nib, pad)                                # (..., n)
    byte = nib[..., 0::2] | (nib[..., 1::2] << 4)
    byte = (byte & 0x7F) - (byte & 0x80)                        # two's complement
    return {"q": byte.astype(jnp.int8), "exp": qd["exp"].astype(jnp.int8)}


def unpack_kv_nibble(packed: dict, fmt: QuantFormat,
                     out_dtype=jnp.bfloat16) -> jax.Array:
    """Decode pack_kv_nibble storage back to values — the jnp reference for
    the in-kernel dequant of ``kernels.paged_attention``."""
    m = fmt.mantissa
    shift = fmt.shift if fmt.kind == "bbfp" else 0
    b = packed["q"].astype(jnp.int32) & 0xFF
    nib = jnp.stack([b & 0xF, (b >> 4) & 0xF], axis=-1)
    nib = nib.reshape(*b.shape[:-1], b.shape[-1] * 2)
    cb, pad = _to_blocks(nib, fmt.block)
    mag = cb & 7
    mant = mag & (2**m - 1)
    flag = mag >> m
    step_log2 = packed["exp"].astype(jnp.int32)[..., None] - m + 1 + flag * shift
    v = jnp.where(cb & 8 != 0, -mant, mant).astype(jnp.float32) \
        * jnp.exp2(step_log2.astype(jnp.float32))
    return _from_blocks(v, pad).astype(out_dtype)


# ---------------------------------------------------------------------------
# format metadata (Table I)
# ---------------------------------------------------------------------------

def equivalent_bit_width(fmt: QuantFormat, block: int | None = None) -> float:
    """Bits per element as stored (Table I 'Equivalent Bit-Width').

    BFPm  : sign + m mantissa + shared exp amortised      -> 1 + m + 5/N
    BBFP  : sign + flag + m mantissa + shared exp         -> 2 + m + 5/N
    FP16  : 16.  INTk: k (+ fp scale amortised, like BFP exponent).
    Matches the paper: BFP8@32 -> 9.16, BFP6@32 -> 7.16, BBFP(8,4)@32 -> 10.16,
    BBFP(6,3)@32 -> 8.16.
    """
    n = block or fmt.block
    if fmt.kind == "none":
        return 16.0
    if fmt.kind == "int":
        return fmt.mantissa + SHARED_EXPONENT_BITS / n
    if fmt.kind == "bfp":
        return 1 + fmt.mantissa + SHARED_EXPONENT_BITS / n
    return 2 + fmt.mantissa + SHARED_EXPONENT_BITS / n


def memory_efficiency(fmt: QuantFormat, block: int | None = None) -> float:
    """Table I 'Mem Eff.' = 16 / equivalent_bit_width."""
    return 16.0 / equivalent_bit_width(fmt, block)


# ---------------------------------------------------------------------------
# reference BBFP matmul (oracle used by kernels/ref.py and tests)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("fmt",))
def bbfp_matmul_packed_ref(a: jax.Array, q: jax.Array, scale: jax.Array,
                           fmt: QuantFormat = BBFP42) -> jax.Array:
    """C = Q(a) @ W_packed with the weight side already integer-decomposed
    (pack_weight storage: q (K, N) int, scale (K/32, N)): only the activation
    is quantised, then the same per-K-block integer dot + two-scale multiply
    as ``bbfp_matmul_ref``. The jnp fallback of the packed Pallas kernel."""
    qa, sa = to_int_repr(a, fmt)                  # (M, nb, B), (M, nb)
    k, n = q.shape
    nb = scale.shape[0]
    qb = q.astype(jnp.float32).reshape(nb, k // nb, n)
    blk = jnp.einsum("mkb,kbn->mnk", qa.astype(jnp.float32), qb)
    return jnp.einsum("mnk,mk,kn->mn", blk, sa, scale)


@partial(jax.jit, static_argnames=("a_fmt", "b_fmt"))
def bbfp_matmul_ref(a: jax.Array, b: jax.Array,
                    a_fmt: QuantFormat = BBFP42,
                    b_fmt: QuantFormat | None = None) -> jax.Array:
    """C = quant(A) @ quant(B) computed exactly as the accelerator would:
    per-K-block integer mantissa dot, scaled by the two shared exponents
    (Eq. 7), accumulated across blocks in fp32 (the 'FP adder').

    a: (M, K), b: (K, N). Block dim = K.
    """
    b_fmt = b_fmt or a_fmt
    qa, sa = to_int_repr(a, a_fmt)                # (M, nb, B), (M, nb)
    qb, sb = to_int_repr(b.T, b_fmt)              # (N, nb, B), (N, nb)
    # integer block dot: (M, N, nb) = sum_B qa * qb   (exact in fp32 for our ranges)
    blk = jnp.einsum("mkb,nkb->mnk", qa.astype(jnp.float32), qb.astype(jnp.float32))
    return jnp.einsum("mnk,mk,nk->mn", blk, sa, sb)
