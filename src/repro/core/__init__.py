"""Core: the paper's BBFP data format, error analysis and nonlinear unit."""
from repro.core.bbfp import (  # noqa: F401
    QuantFormat, parse_format, fake_quant, quantize, dequantize,
    to_int_repr, folded_max, equivalent_bit_width, memory_efficiency,
    bbfp_matmul_ref, FORMATS, DEFAULT_BLOCK,
    FP_NONE, BFP4, BFP6, BFP8, BFP10, BBFP31, BBFP32, BBFP42, BBFP43,
    BBFP63, BBFP64, BBFP65, BBFP105, INT8,
)
from repro.core.nonlinear import (  # noqa: F401
    softmax_bbfp, silu_bbfp, gelu_bbfp, lut_apply, get_lut, build_lut,
    softmax_lut, silu_lut, gelu_lut,
    softmax_bfp_naive, silu_bfp_naive, LutSpec,
)
from repro.core import error, overlap  # noqa: F401
