"""Quantisation-error analysis (paper §III.B, Eq. 8).

Eq. 8 (Kalliojarvi & Astola): for round-to-nearest block floating point,
    sigma^2 = 2^(-2 Lm) / 12 * sum_i p(gamma_i) 2^(2 gamma_i)
i.e. the error variance is the mean of step^2/12 over the distribution of the
(per-element effective) block exponent.  BBFP lowers sigma^2 purely by moving
probability mass of the effective exponent downward (flag=0 elements use a
step 2^(m-o) smaller than BFP's).  We expose both the closed-form estimate
(from the quantiser's actual steps) and empirical MSE/SNR.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bbfp as B


def _steps(x: jax.Array, fmt: B.QuantFormat) -> jax.Array:
    """Per-element quantisation step actually used by the quantiser."""
    x_ = jnp.moveaxis(x, -1, -1).astype(jnp.float32)
    xb, _ = B._to_blocks(x_, fmt.block)
    e_s = B.shared_exponent(xb, fmt)[..., None]
    if fmt.kind == "bfp":
        return jnp.broadcast_to(jnp.exp2((e_s - fmt.mantissa + 1).astype(jnp.float32)), xb.shape)
    e = B._exponent(xb)
    flag = (e > e_s).astype(jnp.int32)
    return jnp.exp2((e_s - fmt.mantissa + 1 + flag * fmt.shift).astype(jnp.float32))


def theoretical_variance(x: jax.Array, fmt: B.QuantFormat) -> jax.Array:
    """Eq. 8 evaluated with the empirical exponent pmf: E[step^2] / 12."""
    s = _steps(x, fmt)
    return jnp.mean(s.astype(jnp.float32) ** 2) / 12.0


def empirical_mse(x: jax.Array, fmt: B.QuantFormat) -> jax.Array:
    y = B.fake_quant(x, fmt)
    return jnp.mean((x - y).astype(jnp.float32) ** 2)


def snr_db(x: jax.Array, fmt: B.QuantFormat) -> jax.Array:
    """Signal-to-quantisation-noise ratio in dB (higher is better)."""
    mse = empirical_mse(x, fmt)
    sig = jnp.mean(x.astype(jnp.float32) ** 2)
    return 10.0 * jnp.log10(sig / jnp.maximum(mse, 1e-30))


def llm_activation_sample(key: jax.Array, shape=(4096, 512),
                          outlier_frac: float = 1e-3,
                          outlier_scale: float = 40.0) -> jax.Array:
    """Synthetic tensor matched to Fig. 1(a): ~N(0,1) bulk plus a sparse
    heavy tail (channel-correlated outliers, as observed in OPT/Llama)."""
    k1, k2, k3 = jax.random.split(key, 3)
    bulk = jax.random.normal(k1, shape)
    mask = jax.random.bernoulli(k2, outlier_frac, shape)
    out = jax.random.normal(k3, shape) * outlier_scale
    return jnp.where(mask, out, bulk).astype(jnp.float32)
