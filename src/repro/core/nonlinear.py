"""Exponent-segmented LUT nonlinear unit (paper §IV.B).

The paper's unit:
  1. Align-Exponent: inputs are converted FP16 -> BBFP(10,5); a block shares
     one 5-bit exponent.
  2. Segmented LUT: the function's value table is split into sub-tables, one
     per (shared exponent, flag, sign) segment (2^5 x 2 in principle; 18 are
     materialised for softmax's exp, 24 for SiLU).  The sub-table for the
     block's shared exponent is loaded, and the top 7 bits of the mantissa are
     *directly* the address (no FP->index mapping as in float LUTs).
  3. Fixed-point post-ops: max unit, adder tree, Div unit implement
     softmax = exp(x - max) / sum;  SiLU = x / (1 + e^-x);  GELU likewise.

TPU adaptation: each sub-table is 2^7 = 128 entries; the whole table bank for
a function is <= 64*128 fp32 = 32 KiB, i.e. resident in VMEM.  Sub-table
select + address formation become a single gather with a composite index
(jnp.take), which is exactly what the Pallas kernel does per block.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bbfp as B

ADDRESS_BITS = 7  # paper: "the address width of each LUT being 7-bit"
EXP_LUT_RANGE = -32.0  # exp-unit input domain (bounded -> few sub-tables)


@dataclasses.dataclass(frozen=True)
class LutSpec:
    """A materialised segmented LUT for one scalar function.

    table is a concrete *numpy* array (2, 2, n_exp, 2^ADDRESS_BITS) indexed
    [sign][flag][e][addr] — numpy so that lazily building it under an ambient
    jit trace can never cache a tracer."""
    name: str
    fmt: B.QuantFormat          # BBFP(10,5) in the paper
    table: np.ndarray
    e_min: int
    e_max: int

    @property
    def n_subtables(self) -> int:
        """Number of non-trivial sub-tables (paper reports 18 for exp, 24 SiLU)."""
        t = np.asarray(self.table)
        nz = 0
        for s in range(2):
            for f in range(2):
                for e in range(t.shape[2]):
                    col = t[s, f, e]
                    if not (np.allclose(col, col[0])):
                        nz += 1
        return nz


def build_lut(fn: Callable[[np.ndarray], np.ndarray], name: str,
              fmt: B.QuantFormat = B.BBFP105,
              e_range: tuple[int, int] = (-16, 15),
              quantize_entries: bool = True) -> LutSpec:
    """Tabulate fn at every representable BBFP bucket centre.

    For segment (sign s, flag f, shared exp e): element value is
       v = s * (addr_center) * 2^(e - m + 1 + f*shift)
    with addr in [0, 2^A), addr_center = (addr + 0.5) * 2^(m - A) (the 10-bit
    mantissa's top-7-bit bucket centre).
    """
    m, sh = fmt.mantissa, fmt.shift
    e_min, e_max = e_range
    n_exp = e_max - e_min + 1
    addr = (np.arange(2**ADDRESS_BITS, dtype=np.float64) + 0.5) * 2 ** (m - ADDRESS_BITS)
    tab = np.zeros((2, 2, n_exp, 2**ADDRESS_BITS), np.float64)
    for si, s in enumerate((1.0, -1.0)):
        for f in (0, 1):
            for ei, e in enumerate(range(e_min, e_max + 1)):
                x = s * addr * 2.0 ** (e - m + 1 + f * sh)
                tab[si, f, ei] = fn(x)
    if quantize_entries:
        # paper: "each entry in the sub-table can be converted from FP16 to
        # BBFP" so the LUT output stays in-format for the next fixed-point op.
        # numpy (not jnp) so tables stay concrete even when built under a jit
        # trace (get_lut may first be hit inside a traced model apply).
        tab = _np_fake_quant(tab.astype(np.float32), fmt)
    return LutSpec(name, fmt, np.asarray(tab, np.float32), e_min, e_max)


def _np_fake_quant(t: np.ndarray, fmt: B.QuantFormat) -> np.ndarray:
    """numpy mirror of bbfp.fake_quant along the last dim (block 32)."""
    m, sh = fmt.mantissa, fmt.shift
    *lead, n = t.shape
    pad = (-n) % B.DEFAULT_BLOCK
    x = np.pad(t, [(0, 0)] * len(lead) + [(0, pad)]) if pad else t
    x = x.reshape(*lead, -1, B.DEFAULT_BLOCK).astype(np.float64)
    ax = np.abs(x)
    e = np.where(ax == 0, B._EXP_MIN,
                 np.clip(np.floor(np.log2(np.maximum(ax, 1e-300))), B._EXP_MIN, B._EXP_MAX)
                 ).astype(np.int64)
    e_max = e.max(-1)
    if fmt.kind == "bfp":
        e_s, flag = e_max, np.zeros_like(e)
        sh = 0
    else:
        e_s = np.clip(e_max - sh, B._EXP_MIN, B._EXP_MAX)
        flag = (e > e_s[..., None]).astype(np.int64)
    step = 2.0 ** (e_s[..., None] - m + 1 + flag * sh)
    q = np.clip(np.round(ax / step), 0, 2**m - 1)
    y = np.where(x < 0, -q, q) * step
    y = y.reshape(*lead, -1)[..., :n]
    return y.astype(np.float32)


@partial(jax.jit, static_argnames=("spec_static",))
def _lut_apply_impl(x, table, spec_static):
    fmt, e_min, a_bits = spec_static
    x_ = x.astype(jnp.float32)
    xb, pad = B._to_blocks(x_, fmt.block)
    qd = B.quantize_blocked(xb, fmt)
    addr = qd["mantissa"] >> (fmt.mantissa - a_bits)
    sign_idx = (qd["sign"] < 0).astype(jnp.int32)
    e_idx = jnp.clip(qd["exp"] - e_min, 0, table.shape[2] - 1)[..., None]
    n_exp, n_addr = table.shape[2], table.shape[3]
    composite = ((sign_idx * 2 + qd["flag"]) * n_exp + e_idx) * n_addr + addr
    y = jnp.take(table.reshape(-1), composite)
    return B._from_blocks(y, pad)


def lut_apply(x: jax.Array, spec: LutSpec) -> jax.Array:
    """Evaluate the tabulated function elementwise via segmented lookup."""
    shape = x.shape
    flat = x.reshape(-1) if x.ndim == 0 else x.reshape(*x.shape[:-1], x.shape[-1])
    y = _lut_apply_impl(flat, spec.table, (spec.fmt, spec.e_min, ADDRESS_BITS))
    return y.reshape(shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# the unit's function library (built lazily, cached)
# ---------------------------------------------------------------------------

_LUT_CACHE: dict[tuple, LutSpec] = {}


def get_lut(name: str, fmt: B.QuantFormat = B.BBFP105) -> LutSpec:
    key = (name, fmt.name, fmt.block)   # block size changes the quantiser
    if key not in _LUT_CACHE:
        fns = {
            # softmax path: exp(x) for x <= 0 (post max-subtraction)
            "exp": lambda x: np.exp(np.clip(x, -87.0, 0.0)),
            # SiLU path per the paper: 1 + e^-x tabulated, Div unit does x / (.)
            "one_plus_exp_neg": lambda x: 1.0 + np.exp(np.clip(-x, -87.0, 87.0)),
            # GELU via tanh approximation's inner transcendental
            "gelu_inner": lambda x: np.tanh(np.sqrt(2 / np.pi) * (x + 0.044715 * x**3)),
            "sigmoid": lambda x: 1.0 / (1.0 + np.exp(np.clip(-x, -87.0, 87.0))),
        }
        _LUT_CACHE[key] = build_lut(fns[name], name, fmt)
    return _LUT_CACHE[key]


def _row_fmt(fmt: B.QuantFormat, row: int) -> B.QuantFormat:
    """The paper's Align Exponent Unit computes ONE shared exponent per
    input vector ('once a shared exponent is calculated during the
    alignment phase, the corresponding sub-table can be loaded'), i.e. the
    nonlinear unit's block = the whole row, not 32."""
    if fmt.kind == "none":
        return fmt
    return dataclasses.replace(fmt, block=max(row, B.DEFAULT_BLOCK))


def softmax_lut(x: jax.Array, axis: int = -1,
                fmt: B.QuantFormat = B.BBFP105,
                where: jax.Array | None = None) -> jax.Array:
    """Softmax via the nonlinear unit: Max Unit -> Sub -> LUT(exp) ->
    Adder Tree -> Div Unit -> Output Encoder (Fig. 6 computation sequence).
    Alignment is per ROW (the Align Exponent Unit), see _row_fmt.

    This is where plain BFP dies (Table IV): the LUT address is the
    row-max-aligned mantissa, so the inputs that matter most for exp — the
    near-zero shifted logits of the *dominant* tokens — fall many bits below
    the row max and lose all address resolution, and the output encoder
    crushes probabilities ~1/seq to zero. BBFP's flag=0 low window gives
    both 2^(m-o) x finer treatment.
    """
    fmt = _row_fmt(fmt, x.shape[axis])
    x_ = jnp.moveaxis(x, axis, -1)
    if where is not None:
        w_ = jnp.moveaxis(jnp.broadcast_to(where, x.shape), axis, -1)
        x_ = jnp.where(w_, x_, -1e30)
    x_max = jax.lax.stop_gradient(jnp.max(x_, axis=-1, keepdims=True))
    shifted = x_ - x_max                                    # <= 0
    # the unit's exp input range is bounded (that's why 18 sub-tables
    # suffice): mask sentinels must NOT reach the Align Exponent Unit or
    # they poison the row's shared exponent. exp(-32) == 0 for our widths.
    shifted = jnp.maximum(shifted, EXP_LUT_RANGE)
    if fmt.kind == "none":
        e = jnp.exp(shifted)
    else:
        e = lut_apply(shifted, get_lut("exp", fmt))
    if where is not None:
        e = jnp.where(w_, e, 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)              # adder tree (fp32)
    out = e / jnp.maximum(denom, 1e-30)                     # div unit
    if fmt.kind != "none":
        out = B.fake_quant(out, fmt)                        # output encoder
    return jnp.moveaxis(out, -1, axis).astype(x.dtype)


def softmax_bbfp(x: jax.Array, axis: int = -1,
                 fmt: B.QuantFormat = B.BBFP105,
                 where: jax.Array | None = None) -> jax.Array:
    return softmax_lut(x, axis=axis, fmt=fmt, where=where)


def silu_bbfp(x: jax.Array, fmt: B.QuantFormat = B.BBFP105) -> jax.Array:
    """SiLU = x / (1 + e^-x): LUT gives the denominator, Div Unit divides.
    Row-aligned like the paper's Align Exponent Unit; the Div Unit saturates
    (fixed-point hardware) so a denominator quantised toward 0 can't inf."""
    if fmt.kind == "none":
        return jax.nn.silu(x)
    denom = lut_apply(x, get_lut("one_plus_exp_neg", _row_fmt(fmt, x.shape[-1])))
    denom = jnp.maximum(denom, jnp.exp2(-16.0))
    return (x / denom).astype(x.dtype)


silu_lut = silu_bbfp  # same unit, format-parameterised


def gelu_bbfp(x: jax.Array, fmt: B.QuantFormat = B.BBFP105) -> jax.Array:
    if fmt.kind == "none":
        return jax.nn.gelu(x)
    inner = lut_apply(x, get_lut("gelu_inner", _row_fmt(fmt, x.shape[-1])))
    return (0.5 * x * (1.0 + inner)).astype(x.dtype)


gelu_lut = gelu_bbfp


def softmax_bfp_naive(x: jax.Array, axis: int = -1,
                      fmt: B.QuantFormat = B.BFP10) -> jax.Array:
    """The BFP10 baseline of Table IV: same pipeline but inputs/outputs pass
    through plain max-aligned BFP quantisation (which crushes the small
    post-softmax probabilities -> the paper's 3x+ PPL blow-up)."""
    x_ = jnp.moveaxis(x, axis, -1)
    xq = B.fake_quant(x_, fmt)
    x_max = jnp.max(xq, axis=-1, keepdims=True)
    e = jnp.exp(xq - x_max)
    e = B.fake_quant(e, fmt)
    out = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    return jnp.moveaxis(B.fake_quant(out, fmt), -1, axis).astype(x.dtype)


def silu_bfp_naive(x: jax.Array, fmt: B.QuantFormat = B.BFP10) -> jax.Array:
    xq = B.fake_quant(x, fmt)
    return B.fake_quant(jax.nn.silu(xq), fmt).astype(x.dtype)
