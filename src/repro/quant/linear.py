"""Quantised linear ops used by every architecture.

Two execution paths, numerically identical (tested):
  * fake-quant path (default): quantise-dequantise both operands along the
    contraction dim, then a normal (bf16/fp32) dot.  Differentiable via STE,
    works everywhere, and is what the dry-run lowers (the quant/dequant ops
    appear in HLO, which is the faithful baseline cost).
  * kernel path: the Pallas bbfp_matmul (int8 MXU per K-block).  Serving
    only, CPU-validated in interpret mode.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import bbfp as B
from repro.core import nonlinear as NL


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """What gets quantised and how. Formats are parse_format strings."""
    linear: str = "none"       # weight+activation format for GEMMs
    nonlinear: str = "none"    # format for softmax/SiLU/GELU (LUT unit)
    kv_cache: str = "none"     # BBFP KV-cache storage format (serving);
    #                            values land on the format's grid at cache
    #                            write (int8 mantissas + per-32-block scales
    #                            once packed storage is used on TPU)
    use_kernel: bool = False   # route GEMMs through the Pallas kernel
    quantize_weights: bool = True
    quantize_acts: bool = True

    @property
    def linear_fmt(self) -> B.QuantFormat:
        return B.parse_format(self.linear)

    @property
    def nonlinear_fmt(self) -> B.QuantFormat:
        return B.parse_format(self.nonlinear)

    @property
    def kv_fmt(self) -> B.QuantFormat:
        return B.parse_format(self.kv_cache)

    @property
    def enabled(self) -> bool:
        return self.linear != "none" or self.nonlinear != "none"


FP = QuantConfig()
# the paper's headline configuration: BBFP(4,2) linears + BBFP(10,5) nonlinear
PAPER = QuantConfig(linear="BBFP(4,2)", nonlinear="BBFP(10,5)")
# beyond-paper serving config: + BBFP(6,3) KV cache (8.16 bits/elt stored)
PAPER_KVQ = QuantConfig(linear="BBFP(4,2)", nonlinear="BBFP(10,5)",
                        kv_cache="BBFP(6,3)")


def qkv_cache(x: jax.Array, qcfg: QuantConfig) -> jax.Array:
    """Quantise K/V onto the BBFP grid at cache-write (blocks along head_dim
    — the contraction dim of the scores dot, so the cached values are
    exactly what a packed int8+scales cache would dequantise to)."""
    if qcfg.kv_cache == "none":
        return x
    return B.fake_quant(x, qcfg.kv_fmt, axis=-1)


def outlier_fake_quant(x: jax.Array, axis: int = -1, block: int = 32) -> jax.Array:
    """Outlier-aware INT4 baseline (Olive/Oltron-style victim pair,
    simplified): the largest-|x| element of each block keeps 8-bit
    precision, the bulk is absmax-INT4. Used by the Fig. 8 comparison."""
    x_ = jnp.moveaxis(x, axis, -1)
    xb, pad = B._to_blocks(x_, block)
    amax_all = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    is_out = jnp.abs(xb) >= amax_all
    bulk = jnp.where(is_out, 0.0, xb)
    amax_bulk = jnp.max(jnp.abs(bulk), axis=-1, keepdims=True)
    scale4 = jnp.where(amax_bulk == 0, 1.0, amax_bulk / 7.0)
    q_bulk = jnp.clip(jnp.round(bulk / scale4), -7, 7) * scale4
    scale8 = jnp.where(amax_all == 0, 1.0, amax_all / 127.0)
    q_out = jnp.clip(jnp.round(xb / scale8), -127, 127) * scale8
    y = B._from_blocks(jnp.where(is_out, q_out, q_bulk), pad)
    y = jnp.moveaxis(y, -1, axis).astype(x.dtype)
    zero = x - jax.lax.stop_gradient(x)
    return zero + jax.lax.stop_gradient(y)


def qact(x: jax.Array, qcfg: QuantConfig, axis: int = -1) -> jax.Array:
    """Quantise an activation tensor along `axis` (contraction dim)."""
    if qcfg.linear == "none" or not qcfg.quantize_acts:
        return x
    if qcfg.linear == "outlier4":
        return outlier_fake_quant(x, axis)
    return B.fake_quant(x, qcfg.linear_fmt, axis=axis)


def qweight(w: jax.Array, qcfg: QuantConfig, axis: int = 0) -> jax.Array:
    if qcfg.linear == "none" or not qcfg.quantize_weights:
        return w
    if qcfg.linear == "outlier4":
        return outlier_fake_quant(w, axis)
    return B.fake_quant(w, qcfg.linear_fmt, axis=axis)


def qdot(x: jax.Array, w: jax.Array, qcfg: QuantConfig) -> jax.Array:
    """y[..., N] = Q(x)[..., K] @ Q(w)[K, N].  Blocks run along K for both
    operands (the PE array consumes K-blocks of 32)."""
    if qcfg.linear == "none":
        return x @ w
    if qcfg.use_kernel:
        from repro.kernels import ops as kops
        return kops.bbfp_matmul(x, w, qcfg.linear).astype(x.dtype)
    xq = qact(x, qcfg, axis=-1)
    wq = qweight(w, qcfg, axis=0)
    return xq @ wq


def qlinear(params: dict, x: jax.Array, qcfg: QuantConfig,
            x_prequantized: bool = False) -> jax.Array:
    """params = {"w": (K, N)[, "b": (N,)]}  OR packed serving form
    {"q": int8 (K, N), "scale": (K/32, N)} (see quant.packed).

    x_prequantized: caller already ran qact on x (§Perf: layers quantise a
    shared input ONCE for wq/wk/wv and gate/up instead of per-projection).
    """
    if "q" in params and "scale" in params:
        if qcfg.use_kernel and qcfg.linear not in ("none", "outlier4"):
            # packed serving FAST path: the weight stays int8+scales all the
            # way to the MXU dot — no dequant in the HLO, ~2x fewer weight
            # bytes read. The kernel quantises the activation itself (packed
            # weights are produced with qcfg.linear's format by pack_params).
            from repro.kernels import ops as kops
            y = kops.bbfp_matmul_packed(x, params, qcfg.linear).astype(x.dtype)
        else:
            # no-kernel path: dequant is one fused multiply into an fp dot;
            # only the activation side is quantised per step.
            w = B.unpack_weight({"q": params["q"], "scale": params["scale"]},
                                out_dtype=x.dtype)
            xq = x if (qcfg.linear == "none" or x_prequantized) else qact(x, qcfg, axis=-1)
            y = xq @ w
    elif x_prequantized and qcfg.linear not in ("none",):
        wq = qweight(params["w"].astype(x.dtype), qcfg, axis=0)
        y = x @ wq
    else:
        y = qdot(x, params["w"].astype(x.dtype), qcfg)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def weight_view(params: dict, out_dtype=None) -> jax.Array:
    """Dense view of a linear's weight whether stored fp ({"w": ...}) or
    packed ({"q", "scale"}, quant.packed). Used by consumers that need the
    raw matrix (e.g. MLA's absorbed-decode einsums); for packed params the
    dequant is one fusable multiply."""
    if "q" in params and "scale" in params:
        return B.unpack_weight(params, out_dtype=out_dtype or jnp.bfloat16)
    w = params["w"]
    return w if out_dtype is None else w.astype(out_dtype)


def qact_shared(x: jax.Array, qcfg: QuantConfig):
    """Quantise an activation that feeds SEVERAL projections once.
    Returns (xq, prequantized_flag). Controlled by the dedup_actquant flag
    so the paper-faithful per-projection baseline stays measurable."""
    from repro.perf_flags import enabled
    if qcfg.linear in ("none", "outlier4") or not qcfg.quantize_acts \
            or not enabled("dedup_actquant"):
        return x, False
    return qact(x, qcfg, axis=-1), True


def qsoftmax(x: jax.Array, qcfg: QuantConfig, axis: int = -1,
             where: jax.Array | None = None) -> jax.Array:
    if qcfg.nonlinear == "none":
        if where is not None:
            x = jnp.where(where, x, -1e30)
        return jax.nn.softmax(x.astype(jnp.float32), axis=axis).astype(x.dtype)
    return NL.softmax_lut(x.astype(jnp.float32), axis=axis,
                          fmt=qcfg.nonlinear_fmt, where=where).astype(x.dtype)


def qsilu(x: jax.Array, qcfg: QuantConfig) -> jax.Array:
    if qcfg.nonlinear == "none":
        return jax.nn.silu(x)
    return NL.silu_lut(x.astype(jnp.float32), fmt=qcfg.nonlinear_fmt).astype(x.dtype)


def qgelu(x: jax.Array, qcfg: QuantConfig) -> jax.Array:
    if qcfg.nonlinear == "none":
        return jax.nn.gelu(x)
    return NL.gelu_bbfp(x.astype(jnp.float32), fmt=qcfg.nonlinear_fmt).astype(x.dtype)


def qexp_for_online_softmax(x: jax.Array, qcfg: QuantConfig) -> jax.Array:
    """exp(x) for x<=0, used inside chunked/online softmax where the full row
    never materialises: the LUT unit still supplies exp, the running
    rescale stays fp32 (exact powers of e cancel in the final division).
    Inputs are clamped to the unit's bounded domain so masked sentinels
    can't poison the block exponents (see nonlinear.EXP_LUT_RANGE)."""
    if qcfg.nonlinear == "none":
        return jnp.exp(x)
    xc = jnp.maximum(x.astype(jnp.float32), NL.EXP_LUT_RANGE)
    return NL.lut_apply(xc, NL.get_lut("exp", qcfg.nonlinear_fmt)).astype(x.dtype)
