"""Quantisation plumbing: QuantConfig + quantised linear/nonlinear ops.

This is how the paper's technique enters every model: all weight/activation
GEMMs go through ``qdot`` (BBFP/BFP/INT fake-quant with STE, or the Pallas
integer kernel on the serving path), and softmax/SiLU/GELU go through the
segmented-LUT nonlinear unit.
"""
from repro.quant.linear import QuantConfig, qdot, qlinear, qact  # noqa: F401
