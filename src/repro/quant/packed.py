"""Offline weight packing for serving (§Perf iteration A1/C2).

`pack_params` walks a trained/served param pytree and replaces every
quantisable linear weight {"w": (..., K, N)} with
{"q": int8 (..., K, N), "scale": f32 (..., K/32, N)} — the BBFP storage
format (Table I): per-step weight re-quantisation disappears from the HLO
and weight reads shrink 16b -> ~8.16b. `qlinear` transparently accepts
either form. Numerically identical to fake-quantising the weight each step
(quantisation is deterministic; tested).
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.core import bbfp as B

# leaves eligible for packing: same projection set the sharding rules know.
_PACKABLE = re.compile(
    r"(wq|wk|wv|wo|w_dkv|w_uk|w_uv|in_proj|out_proj|proj_x|proj_gate|"
    r"proj_out|wa|wx|w_gate|w_up|w_down)(/w)?$")
_SKIP = re.compile(r"(embed|lm_head|router|norm|conv|enc_pos|dec_pos)")


def _should_pack(path: str, leaf) -> bool:
    if _SKIP.search(path):
        return False
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if leaf.shape[-2] % B.DEFAULT_BLOCK != 0:
        return False
    return bool(_PACKABLE.search(path))


def pack_params(params, fmt: B.QuantFormat):
    """Returns a new pytree with packable weights replaced by packed dicts."""
    def walk(node, path=""):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                p = f"{path}/{k}" if path else k
                if k == "w" and _should_pack(p, v):
                    return {**{kk: vv for kk, vv in node.items() if kk != "w"},
                            **B.pack_weight(v, fmt)}
                if not isinstance(v, dict) and not isinstance(v, (list, tuple)) \
                        and _should_pack(p, v):
                    out[k] = B.pack_weight(v, fmt)
                else:
                    out[k] = walk(v, p)
            return out
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(v, f"{path}/{i}") for i, v in enumerate(node))
        return node

    return walk(params)


def is_packed(params_like: dict) -> bool:
    return isinstance(params_like, dict) and "q" in params_like and "scale" in params_like
