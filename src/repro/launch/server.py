"""Asyncio serving front door over the continuous-batching engine.

``AsyncServer`` turns the PR-5 engine (Scheduler / KVCacheManager /
ModelRunner behind ``ContinuousBatcher``) into a process-shaped service:

  * PER-REQUEST STREAMING — ``submit`` returns a ``TokenStream`` (an async
    iterator); each engine tick's freshly decoded tokens land in the
    request's own ``asyncio.Queue`` the moment the stream edge produces
    them, so callers consume tokens while the request is still decoding.
  * SLO CLASSES AND DEADLINES — ``slo`` maps onto the Scheduler's existing
    ``Request.priority`` field through ``SLO_PRIORITY`` (interactive >
    standard > batch), so admission order and preemption-victim selection
    need NO new policy code. ``deadline_s`` is the request's end-to-end
    latency budget; it does not change scheduling, it feeds the goodput
    accounting (a request is "good" iff it finished within its budget).
  * OVERLAPPED ENGINE LOOP — the engine advances via
    ``ContinuousBatcher.step_overlapped``: the host plans tick N+1's
    admissions (queue policy, radix matching, page allocation, prefill
    dispatch) while tick N's decode is in flight on the device, and blocks
    only at the stream edge (``ModelRunner.decode_collect``). Each tick
    runs in a thread-pool executor so the asyncio event loop keeps
    accepting submissions mid-tick; ALL engine state is touched only from
    inside ``_tick`` (one in flight at a time), so the engine needs no
    locks.
  * GRACEFUL DRAIN — ``shutdown(drain=True)`` stops accepting new
    requests, keeps ticking until the queue, the slots, and the in-flight
    decode are all empty (every accepted stream gets its end-of-stream
    sentinel), then stops the loop. ``drain=False`` cancels the loop and
    fails every open stream with ``ServerClosed``.

SUPERVISION (fault tolerance). The engine loop no longer dies on the
first tick failure:

  * TICK RETRY — a failed engine tick is retried with bounded exponential
    backoff (``tick_retries`` / ``backoff_s``). Chaos faults inject at the
    tick BOUNDARY (before engine state mutates), so a retried tick is
    exact and greedy output stays token-identical to a fault-free run.
    ``ReplicaKilled`` is fatal and never retried.
  * FAILURE ISOLATION — a poisoned request (``ChaosInjector.poison_rids``)
    fails only ITS ``TokenStream``; the request is cancelled out of the
    engine (pages freed, epoch bumped) and the server keeps ticking.
  * PER-REQUEST TIMEOUTS — ``request_timeout_s`` (server default, per-
    submit override) bounds a request's wall clock; an overdue stream is
    cancelled, its pages/slot freed, and its stream fails with
    ``RequestTimeout``.
  * LOAD SHEDDING — under overload, batch-class submissions are rejected
    up front with an explicit ``shed`` outcome instead of queuing past
    their deadline: ``shed_policy='depth'`` sheds at queue depth
    ``shed_depth``; ``'deadline'`` sheds when the projected first-token
    latency (queue depth x EWMA tick time) already exceeds the request's
    deadline. Shed streams terminate with ``RequestShed`` and never touch
    the engine.
  * DEAD-REPLICA SEMANTICS — a fatal failure marks the server dead: open
    streams fail with the cause, ``submit`` raises ``ServerClosed``, and
    the loop RETURNS (so ``shutdown(drain=True)`` on a dead replica does
    not hang or re-raise). A fleet (launch/router.py) reroutes around it.

Every terminal outcome is recorded (``completed`` / ``failed`` /
``timeout`` / ``shed``) and flows into ``metrics()`` / ``counters()`` /
``percentile_rows`` so goodput accounting sees shed and failed work
explicitly rather than by omission.

The closed-loop latency driver (``closed_loop``) lives here too so the
``--serve`` CLI mode and ``benchmarks/serving_latency.py`` share one
arrival process: seeded Poisson arrivals (deterministic inter-arrival
gaps), per-request TTFT / TPOT / deadline bookkeeping server-side.
Clients tolerate failed/shed streams: the stream's terminal exception is
recorded in its metrics row, never raised out of the driver.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import time

import numpy as np

from repro.runtime.batcher import Request
from repro.runtime.faults import (InjectedFailure, ReplicaKilled,
                                  StragglerMonitor)

# SLO class -> Scheduler priority (higher admits first and preempts lower;
# the scheduler breaks ties by arrival, so same-class traffic stays FIFO)
SLO_PRIORITY = {"batch": 0, "standard": 1, "interactive": 2}


class ServerClosed(RuntimeError):
    """Raised to submitters after shutdown and into non-drained streams."""


class RequestShed(RuntimeError):
    """The request was rejected by the load shedder (explicit outcome:
    the stream terminates with this instead of queuing past its SLO)."""


class RequestTimeout(RuntimeError):
    """The request exceeded its wall-clock budget: its stream is failed
    and its engine state (slot, pages) reclaimed."""


@dataclasses.dataclass
class _Stream:
    """Server-side record of one streaming request."""
    req: Request
    queue: asyncio.Queue
    slo: str
    deadline_s: float | None
    t_submit: float
    timeout_s: float | None = None   # wall-clock abort budget
    t_first: float | None = None     # first token emission (TTFT edge)
    t_done: float | None = None
    outcome: str = "completed"       # completed | failed | timeout | shed


@dataclasses.dataclass
class RequestMetrics:
    """Per-request latency record (seconds; populated after completion)."""
    rid: int
    slo: str
    n_tokens: int
    ttft_s: float                    # submit -> first streamed token
    tpot_s: float                    # mean inter-token time after the first
    latency_s: float                 # submit -> stream end
    deadline_s: float | None
    ok: bool                         # finished within its deadline (goodput)
    t_submit_s: float = 0.0          # absolute (perf_counter) submit time
    t_done_s: float = 0.0            # absolute (perf_counter) completion
    outcome: str = "completed"       # terminal outcome (see _Stream)


class TokenStream:
    """Async iterator over one request's streamed token ids."""

    def __init__(self, rec: _Stream):
        self._rec = rec

    @property
    def request(self) -> Request:
        return self._rec.req

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        item = await self._rec.queue.get()
        if item is None:
            raise StopAsyncIteration
        if isinstance(item, BaseException):
            raise item
        return item


class AsyncServer:
    """Asyncio front door over a paged-layout ``ContinuousBatcher``."""

    def __init__(self, batcher, *, idle_poll_s: float = 0.02,
                 chaos=None, request_timeout_s: float | None = None,
                 shed_policy: str = "none", shed_depth: int | None = None,
                 tick_retries: int = 2, backoff_s: float = 0.05):
        assert batcher.paged, "AsyncServer requires kv_layout='paged' " \
            "(the overlapped loop pipelines the paged engine)"
        assert shed_policy in ("none", "depth", "deadline"), shed_policy
        self.bat = batcher
        self.idle_poll_s = idle_poll_s
        self.chaos = chaos
        self.request_timeout_s = request_timeout_s
        self.shed_policy = shed_policy
        self.shed_depth = shed_depth
        self.tick_retries = tick_retries
        self.backoff_s = backoff_s
        self._staged: collections.deque = collections.deque()
        self._streams: dict[int, _Stream] = {}
        self._done: list[_Stream] = []
        self._wake = asyncio.Event()
        self._closing = False
        self._task: asyncio.Task | None = None
        self._next_rid = 0
        self._tick_no = 0                # completed-tick counter (chaos key)
        self._dead: BaseException | None = None
        self._mon = StragglerMonitor()   # tick wall-time EWMA -> health/shed
        self.shed = 0
        self.timeouts = 0
        self.tick_failures = 0           # retried tick failures survived

    # -- client surface ----------------------------------------------------

    async def start(self):
        assert self._task is None, "server already started"
        self._task = asyncio.create_task(self._engine_loop())

    def _should_shed(self, slo: str, deadline_s: float | None) -> bool:
        """Shed decision at submit time. Only batch-class traffic is
        sheddable (interactive/standard keep their admission-order SLO);
        the decision is made before the request touches any engine state,
        so a shed request costs nothing."""
        if self.shed_policy == "none" or slo != "batch":
            return False
        depth = len(self._staged) + self.bat.sched.outstanding()
        if self.shed_policy == "depth":
            return self.shed_depth is not None and depth >= self.shed_depth
        # "deadline": shed when the projected first-token latency at the
        # current depth (depth x EWMA tick time) already blows the budget
        if deadline_s is None or self._mon.mean_s == 0.0:
            return False
        return depth * self._mon.mean_s > deadline_s

    def submit(self, prompt, max_new: int, *, slo: str = "standard",
               deadline_s: float | None = None,
               priority: int | None = None,
               timeout_s: float | None = None) -> TokenStream:
        """Accept one request and return its token stream. `slo` picks the
        scheduler priority (see SLO_PRIORITY); an explicit `priority`
        overrides it. `deadline_s` is the end-to-end budget used by the
        goodput accounting only; `timeout_s` (default: the server's
        ``request_timeout_s``) is the hard wall-clock abort budget."""
        if self._dead is not None:
            raise ServerClosed(f"replica is dead: {self._dead}")
        if self._closing:
            raise ServerClosed("server is shutting down; request rejected")
        if slo not in SLO_PRIORITY:
            raise ValueError(f"unknown SLO class {slo!r}; "
                             f"one of {sorted(SLO_PRIORITY)}")
        rid, self._next_rid = self._next_rid, self._next_rid + 1
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      priority=SLO_PRIORITY[slo] if priority is None
                      else priority)
        now = time.perf_counter()
        rec = _Stream(req=req, queue=asyncio.Queue(), slo=slo,
                      deadline_s=deadline_s, t_submit=now,
                      timeout_s=timeout_s if timeout_s is not None
                      else self.request_timeout_s)
        if self._should_shed(slo, deadline_s):
            self.shed += 1
            rec.outcome, rec.t_done = "shed", now
            rec.queue.put_nowait(RequestShed(
                f"request {rid} shed under overload "
                f"(policy={self.shed_policy})"))
            self._done.append(rec)
            return TokenStream(rec)
        self._streams[rid] = rec
        self._staged.append(req)
        self._wake.set()
        return TokenStream(rec)

    async def shutdown(self, drain: bool = True):
        """Stop the engine loop. ``drain=True`` serves everything already
        accepted first (graceful); ``drain=False`` cancels immediately and
        fails open streams with ``ServerClosed``."""
        self._closing = True
        self._wake.set()
        if self._task is None:
            return
        if drain:
            await self._task
        else:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._fail_open_streams(ServerClosed("server shut down "
                                                 "without drain"))
        self._task = None

    # -- engine loop -------------------------------------------------------

    def _has_engine_work(self) -> bool:
        return bool(self._staged) or self.bat._inflight is not None \
            or self.bat.sched.outstanding() > 0

    def _tick(self):
        """One engine advance — runs in the executor thread. The ONLY code
        that touches the batcher, so the engine sees strictly serial calls
        (at most one _tick is in flight at any moment). The chaos hook
        fires FIRST, at the tick boundary: a raise here leaves the engine
        untouched, so the supervised retry of the same tick number is
        exact. The tick counter advances only on success — a retried tick
        re-enters ``on_tick`` with the same key and the raise-once
        bookkeeping skips. Submit-time validation errors fail only the
        offending request (returned as rejects), not the tick."""
        tick = self._tick_no
        if self.chaos is not None:
            self.chaos.on_tick(tick)
        rejects = []
        while self._staged:
            req = self._staged.popleft()
            try:
                self.bat.submit(req)
            except ValueError as e:       # invalid request: isolate it
                rejects.append((req, e))
        t0 = time.perf_counter()
        _, events = self.bat.step_overlapped()
        self._mon.observe(tick, time.perf_counter() - t0)
        self._tick_no = tick + 1
        return events, rejects

    def _abort_stream(self, rid: int, exc: BaseException, outcome: str):
        """Terminate ONE stream with `exc` (failure isolation): cancel the
        request out of the engine — queued: dequeued; running: slot
        retired, pages released, epoch bumped so the in-flight decode's
        token is discarded — and deliver the cause to its consumer. Only
        called from the event-loop thread while no tick is executing."""
        rec = self._streams.pop(rid, None)
        if rec is None:
            return
        try:                  # accepted but not yet inside the engine
            self._staged.remove(rec.req)
        except ValueError:
            self.bat.cancel(rid)
        rec.outcome = outcome
        rec.t_done = time.perf_counter()
        rec.queue.put_nowait(exc)
        self._done.append(rec)

    def _expire_timeouts(self):
        now = time.perf_counter()
        for rid, rec in list(self._streams.items()):
            if rec.timeout_s is not None and \
                    now - rec.t_submit > rec.timeout_s:
                self.timeouts += 1
                self._abort_stream(rid, RequestTimeout(
                    f"request {rid} exceeded its {rec.timeout_s:g}s "
                    f"budget"), "timeout")

    def _dispatch_events(self, events):
        now = time.perf_counter()
        for req, toks, done in events:
            rec = self._streams.get(req.rid)
            if rec is None:
                continue
            if self.chaos is not None and self.chaos.is_poisoned(req.rid):
                self._abort_stream(req.rid, InjectedFailure(
                    f"poisoned request {req.rid}"), "failed")
                continue
            if rec.t_first is None:
                rec.t_first = now
            for t in toks:
                rec.queue.put_nowait(t)
            if done:
                rec.t_done = now
                rec.queue.put_nowait(None)          # end-of-stream sentinel
                self._done.append(self._streams.pop(req.rid))

    def _fail_open_streams(self, exc: BaseException,
                           outcome: str = "failed"):
        now = time.perf_counter()
        for rec in self._streams.values():
            if rec.t_done is None:
                rec.outcome, rec.t_done = outcome, now
                rec.queue.put_nowait(exc)
                self._done.append(rec)
        self._streams.clear()

    def _die(self, exc: BaseException):
        """Fatal failure: mark the replica dead, fail every open stream
        with the cause, stop accepting. The engine loop RETURNS after this
        (no re-raise), so ``shutdown(drain=True)`` on a dead replica joins
        cleanly and a fleet can keep serving through the survivors."""
        self._dead = exc
        self._closing = True
        self._fail_open_streams(exc)

    async def _engine_loop(self):
        loop = asyncio.get_running_loop()
        failures = 0
        while True:
            self._expire_timeouts()
            if not self._has_engine_work():
                if self._closing:
                    return                           # drained: graceful stop
                self._wake.clear()
                if self._has_engine_work():          # raced a submit
                    continue
                try:
                    await asyncio.wait_for(self._wake.wait(),
                                           timeout=self.idle_poll_s)
                except asyncio.TimeoutError:
                    pass
                continue
            try:
                events, rejects = await loop.run_in_executor(None, self._tick)
            except ReplicaKilled as e:               # fatal: never retried
                self._die(e)
                return
            except Exception as e:                   # retry with backoff
                self.tick_failures += 1
                failures += 1
                if failures > self.tick_retries:
                    self._die(e)
                    return
                await asyncio.sleep(self.backoff_s * 2 ** (failures - 1))
                continue
            failures = 0
            for req, exc in rejects:
                self._abort_stream(req.rid, exc, "failed")
            self._dispatch_events(events)

    # -- introspection -----------------------------------------------------

    def metrics(self) -> list[RequestMetrics]:
        """Latency records of every TERMINATED request (completed, failed,
        timed out, or shed), termination order. Non-completed rows carry
        NaN token latencies and ``ok=False`` — goodput accounting sees
        failed/shed work explicitly."""
        out = []
        for rec in self._done:
            n = len(rec.req.out_tokens)
            lat = rec.t_done - rec.t_submit
            completed = rec.outcome == "completed"
            out.append(RequestMetrics(
                rid=rec.req.rid, slo=rec.slo, n_tokens=n,
                ttft_s=(rec.t_first - rec.t_submit)
                if rec.t_first is not None else float("nan"),
                tpot_s=(rec.t_done - rec.t_first) / max(n - 1, 1)
                if completed else float("nan"),
                latency_s=lat, deadline_s=rec.deadline_s,
                ok=completed and (rec.deadline_s is None
                                  or lat <= rec.deadline_s),
                t_submit_s=rec.t_submit, t_done_s=rec.t_done,
                outcome=rec.outcome))
        return out

    @property
    def health(self) -> str:
        """Replica health for fleet routing: ``dead`` (fatal failure),
        ``slow`` (tick wall times straggling per the EWMA monitor), or
        ``ok``."""
        if self._dead is not None:
            return "dead"
        if self._mon.flagged:
            return "slow"
        return "ok"

    def counters(self) -> dict:
        """Engine-loop counters: the overlap proof plus serving stats."""
        b = self.bat
        done = collections.Counter(rec.outcome for rec in self._done)
        return {"overlapped_ticks": b.overlapped_ticks,
                "host_idle_ticks": b.host_idle_ticks,
                "decode_calls": b.decode_calls,
                "prefill_steps": b.prefill_steps,
                "preemptions": b.preemptions,
                "completed": done["completed"],
                "failed": done["failed"],
                "timeouts": done["timeout"],
                "shed": done["shed"],
                "tick_failures": self.tick_failures,
                "health": self.health,
                "open_streams": len(self._streams)}


# -- closed-loop latency driver --------------------------------------------

@dataclasses.dataclass
class WorkItem:
    """One request of a closed-loop workload."""
    prompt: object                   # (P,) int32 token array
    max_new: int
    slo: str = "standard"
    deadline_s: float | None = None


async def closed_loop(server: AsyncServer, workload: list[WorkItem], *,
                      rate: float, seed: int = 0,
                      timeout_s: float = 300.0) -> list[RequestMetrics]:
    """Drive `server` with seeded Poisson arrivals at `rate` requests/s
    and wait for every stream to finish (closed loop: the call returns
    only when the workload has fully drained, so a sweep's rates never
    overlap). Inter-arrival gaps come from a seeded rng — the arrival
    schedule is deterministic for a given (seed, rate, len(workload)).

    Fault-tolerant: a stream failed, shed, or timed out by the server
    delivers its terminal exception to its client here, which records it
    and keeps going — the driver returns the full metrics batch (with
    per-request outcomes) instead of crashing the gather. A submit
    rejected because the server died mid-run is likewise recorded."""
    gaps = np.random.default_rng(seed).exponential(1.0 / rate,
                                                   size=len(workload))
    arrivals = np.cumsum(gaps)

    async def client(delay: float, item: WorkItem):
        await asyncio.sleep(delay)
        try:
            stream = server.submit(item.prompt, item.max_new, slo=item.slo,
                                   deadline_s=item.deadline_s)
        except ServerClosed as e:
            return e
        try:
            return [t async for t in stream]
        except Exception as e:    # terminal outcome is in server.metrics()
            return e

    await asyncio.wait_for(
        asyncio.gather(*[client(float(arrivals[i]), w)
                         for i, w in enumerate(workload)]),
        timeout=timeout_s)
    return server.metrics()


def percentile_rows(metrics: list[RequestMetrics]) -> dict:
    """TTFT/TPOT p50/p95 (microseconds) + goodput over a metrics batch.
    Goodput = deadline-meeting completed requests per second of makespan
    (first submit to last completion). Percentiles are over COMPLETED
    requests only; failed / shed / timed-out rows are counted explicitly
    (`of` stays the total, so goodput degrades when work is lost)."""
    done = [m for m in metrics if m.outcome == "completed"]
    ttft = np.asarray([m.ttft_s for m in done])
    tpot = np.asarray([m.tpot_s for m in done])
    span = (max(m.t_done_s for m in done)
            - min(m.t_submit_s for m in done)) if done else 0.0
    good = sum(m.ok for m in done)

    def pct(a, q):
        return float(np.percentile(a, q)) * 1e6 if len(a) else 0.0

    outcomes = collections.Counter(m.outcome for m in metrics)
    return {"ttft_p50_us": pct(ttft, 50),
            "ttft_p95_us": pct(ttft, 95),
            "tpot_p50_us": pct(tpot, 50),
            "tpot_p95_us": pct(tpot, 95),
            "goodput_rps": good / span if span > 0 else 0.0,
            "good": good, "of": len(metrics),
            "completed": len(done),
            "failed": outcomes["failed"],
            "shed": outcomes["shed"],
            "timed_out": outcomes["timeout"]}
