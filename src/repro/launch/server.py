"""Asyncio serving front door over the continuous-batching engine.

``AsyncServer`` turns the PR-5 engine (Scheduler / KVCacheManager /
ModelRunner behind ``ContinuousBatcher``) into a process-shaped service:

  * PER-REQUEST STREAMING — ``submit`` returns a ``TokenStream`` (an async
    iterator); each engine tick's freshly decoded tokens land in the
    request's own ``asyncio.Queue`` the moment the stream edge produces
    them, so callers consume tokens while the request is still decoding.
  * SLO CLASSES AND DEADLINES — ``slo`` maps onto the Scheduler's existing
    ``Request.priority`` field through ``SLO_PRIORITY`` (interactive >
    standard > batch), so admission order and preemption-victim selection
    need NO new policy code. ``deadline_s`` is the request's end-to-end
    latency budget; it does not change scheduling, it feeds the goodput
    accounting (a request is "good" iff it finished within its budget).
  * OVERLAPPED ENGINE LOOP — the engine advances via
    ``ContinuousBatcher.step_overlapped``: the host plans tick N+1's
    admissions (queue policy, radix matching, page allocation, prefill
    dispatch) while tick N's decode is in flight on the device, and blocks
    only at the stream edge (``ModelRunner.decode_collect``). Each tick
    runs in a thread-pool executor so the asyncio event loop keeps
    accepting submissions mid-tick; ALL engine state is touched only from
    inside ``_tick`` (one in flight at a time), so the engine needs no
    locks.
  * GRACEFUL DRAIN — ``shutdown(drain=True)`` stops accepting new
    requests, keeps ticking until the queue, the slots, and the in-flight
    decode are all empty (every accepted stream gets its end-of-stream
    sentinel), then stops the loop. ``drain=False`` cancels the loop and
    fails every open stream with ``ServerClosed``.

The closed-loop latency driver (``closed_loop``) lives here too so the
``--serve`` CLI mode and ``benchmarks/serving_latency.py`` share one
arrival process: seeded Poisson arrivals (deterministic inter-arrival
gaps), per-request TTFT / TPOT / deadline bookkeeping server-side.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import time

import numpy as np

from repro.runtime.batcher import Request

# SLO class -> Scheduler priority (higher admits first and preempts lower;
# the scheduler breaks ties by arrival, so same-class traffic stays FIFO)
SLO_PRIORITY = {"batch": 0, "standard": 1, "interactive": 2}


class ServerClosed(RuntimeError):
    """Raised to submitters after shutdown and into non-drained streams."""


@dataclasses.dataclass
class _Stream:
    """Server-side record of one streaming request."""
    req: Request
    queue: asyncio.Queue
    slo: str
    deadline_s: float | None
    t_submit: float
    t_first: float | None = None     # first token emission (TTFT edge)
    t_done: float | None = None


@dataclasses.dataclass
class RequestMetrics:
    """Per-request latency record (seconds; populated after completion)."""
    rid: int
    slo: str
    n_tokens: int
    ttft_s: float                    # submit -> first streamed token
    tpot_s: float                    # mean inter-token time after the first
    latency_s: float                 # submit -> stream end
    deadline_s: float | None
    ok: bool                         # finished within its deadline (goodput)
    t_submit_s: float = 0.0          # absolute (perf_counter) submit time
    t_done_s: float = 0.0            # absolute (perf_counter) completion


class TokenStream:
    """Async iterator over one request's streamed token ids."""

    def __init__(self, rec: _Stream):
        self._rec = rec

    @property
    def request(self) -> Request:
        return self._rec.req

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        item = await self._rec.queue.get()
        if item is None:
            raise StopAsyncIteration
        if isinstance(item, BaseException):
            raise item
        return item


class AsyncServer:
    """Asyncio front door over a paged-layout ``ContinuousBatcher``."""

    def __init__(self, batcher, *, idle_poll_s: float = 0.02):
        assert batcher.paged, "AsyncServer requires kv_layout='paged' " \
            "(the overlapped loop pipelines the paged engine)"
        self.bat = batcher
        self.idle_poll_s = idle_poll_s
        self._staged: collections.deque = collections.deque()
        self._streams: dict[int, _Stream] = {}
        self._done: list[_Stream] = []
        self._wake = asyncio.Event()
        self._closing = False
        self._task: asyncio.Task | None = None
        self._next_rid = 0

    # -- client surface ----------------------------------------------------

    async def start(self):
        assert self._task is None, "server already started"
        self._task = asyncio.create_task(self._engine_loop())

    def submit(self, prompt, max_new: int, *, slo: str = "standard",
               deadline_s: float | None = None,
               priority: int | None = None) -> TokenStream:
        """Accept one request and return its token stream. `slo` picks the
        scheduler priority (see SLO_PRIORITY); an explicit `priority`
        overrides it. `deadline_s` is the end-to-end budget used by the
        goodput accounting only."""
        if self._closing:
            raise ServerClosed("server is shutting down; request rejected")
        if slo not in SLO_PRIORITY:
            raise ValueError(f"unknown SLO class {slo!r}; "
                             f"one of {sorted(SLO_PRIORITY)}")
        rid, self._next_rid = self._next_rid, self._next_rid + 1
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      priority=SLO_PRIORITY[slo] if priority is None
                      else priority)
        rec = _Stream(req=req, queue=asyncio.Queue(), slo=slo,
                      deadline_s=deadline_s, t_submit=time.perf_counter())
        self._streams[rid] = rec
        self._staged.append(req)
        self._wake.set()
        return TokenStream(rec)

    async def shutdown(self, drain: bool = True):
        """Stop the engine loop. ``drain=True`` serves everything already
        accepted first (graceful); ``drain=False`` cancels immediately and
        fails open streams with ``ServerClosed``."""
        self._closing = True
        self._wake.set()
        if self._task is None:
            return
        if drain:
            await self._task
        else:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._fail_open_streams(ServerClosed("server shut down "
                                                 "without drain"))
        self._task = None

    # -- engine loop -------------------------------------------------------

    def _has_engine_work(self) -> bool:
        return bool(self._staged) or self.bat._inflight is not None \
            or self.bat.sched.outstanding() > 0

    def _tick(self):
        """One engine advance — runs in the executor thread. The ONLY code
        that touches the batcher, so the engine sees strictly serial calls
        (at most one _tick is in flight at any moment)."""
        while self._staged:
            self.bat.submit(self._staged.popleft())
        _, events = self.bat.step_overlapped()
        return events

    def _dispatch_events(self, events):
        now = time.perf_counter()
        for req, toks, done in events:
            rec = self._streams.get(req.rid)
            if rec is None:
                continue
            if rec.t_first is None:
                rec.t_first = now
            for t in toks:
                rec.queue.put_nowait(t)
            if done:
                rec.t_done = now
                rec.queue.put_nowait(None)          # end-of-stream sentinel
                self._done.append(self._streams.pop(req.rid))

    def _fail_open_streams(self, exc: BaseException):
        for rec in self._streams.values():
            if rec.t_done is None:
                rec.queue.put_nowait(exc)
        self._streams.clear()

    async def _engine_loop(self):
        loop = asyncio.get_running_loop()
        while True:
            if not self._has_engine_work():
                if self._closing:
                    return                           # drained: graceful stop
                self._wake.clear()
                if self._has_engine_work():          # raced a submit
                    continue
                try:
                    await asyncio.wait_for(self._wake.wait(),
                                           timeout=self.idle_poll_s)
                except asyncio.TimeoutError:
                    pass
                continue
            try:
                events = await loop.run_in_executor(None, self._tick)
            except Exception as e:                   # engine failure: fail
                self._fail_open_streams(e)           # open streams loudly
                raise
            self._dispatch_events(events)

    # -- introspection -----------------------------------------------------

    def metrics(self) -> list[RequestMetrics]:
        """Latency records of every COMPLETED request, completion order."""
        out = []
        for rec in self._done:
            n = len(rec.req.out_tokens)
            lat = rec.t_done - rec.t_submit
            out.append(RequestMetrics(
                rid=rec.req.rid, slo=rec.slo, n_tokens=n,
                ttft_s=rec.t_first - rec.t_submit,
                tpot_s=(rec.t_done - rec.t_first) / max(n - 1, 1),
                latency_s=lat, deadline_s=rec.deadline_s,
                ok=rec.deadline_s is None or lat <= rec.deadline_s,
                t_submit_s=rec.t_submit, t_done_s=rec.t_done))
        return out

    def counters(self) -> dict:
        """Engine-loop counters: the overlap proof plus serving stats."""
        b = self.bat
        return {"overlapped_ticks": b.overlapped_ticks,
                "host_idle_ticks": b.host_idle_ticks,
                "decode_calls": b.decode_calls,
                "prefill_steps": b.prefill_steps,
                "preemptions": b.preemptions,
                "completed": len(self._done),
                "open_streams": len(self._streams)}


# -- closed-loop latency driver --------------------------------------------

@dataclasses.dataclass
class WorkItem:
    """One request of a closed-loop workload."""
    prompt: object                   # (P,) int32 token array
    max_new: int
    slo: str = "standard"
    deadline_s: float | None = None


async def closed_loop(server: AsyncServer, workload: list[WorkItem], *,
                      rate: float, seed: int = 0,
                      timeout_s: float = 300.0) -> list[RequestMetrics]:
    """Drive `server` with seeded Poisson arrivals at `rate` requests/s
    and wait for every stream to finish (closed loop: the call returns
    only when the workload has fully drained, so a sweep's rates never
    overlap). Inter-arrival gaps come from a seeded rng — the arrival
    schedule is deterministic for a given (seed, rate, len(workload))."""
    gaps = np.random.default_rng(seed).exponential(1.0 / rate,
                                                   size=len(workload))
    arrivals = np.cumsum(gaps)

    async def client(delay: float, item: WorkItem):
        await asyncio.sleep(delay)
        stream = server.submit(item.prompt, item.max_new, slo=item.slo,
                               deadline_s=item.deadline_s)
        return [t async for t in stream]

    await asyncio.wait_for(
        asyncio.gather(*[client(float(arrivals[i]), w)
                         for i, w in enumerate(workload)]),
        timeout=timeout_s)
    return server.metrics()


def percentile_rows(metrics: list[RequestMetrics]) -> dict:
    """TTFT/TPOT p50/p95 (microseconds) + goodput over a metrics batch.
    Goodput = deadline-meeting completed requests per second of makespan
    (first submit to last completion)."""
    ttft = np.asarray([m.ttft_s for m in metrics])
    tpot = np.asarray([m.tpot_s for m in metrics])
    span = (max(m.t_done_s for m in metrics)
            - min(m.t_submit_s for m in metrics)) if metrics else 0.0
    good = sum(m.ok for m in metrics)
    return {"ttft_p50_us": float(np.percentile(ttft, 50)) * 1e6,
            "ttft_p95_us": float(np.percentile(ttft, 95)) * 1e6,
            "tpot_p50_us": float(np.percentile(tpot, 50)) * 1e6,
            "tpot_p95_us": float(np.percentile(tpot, 95)) * 1e6,
            "goodput_rps": good / span if span > 0 else 0.0,
            "good": good, "of": len(metrics)}
