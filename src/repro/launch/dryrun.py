import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import: jax locks the device count on first init.

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                    # noqa: E402
from repro.launch import hlo_analysis as H   # noqa: E402
from repro.launch import sharding as S       # noqa: E402
from repro.launch import specs as SP         # noqa: E402
from repro.launch import steps as ST         # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import partitioning as PT  # noqa: E402
from repro.optim import adamw as O           # noqa: E402
from repro.quant import linear as Q          # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun.json")


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)}


def lower_cell(arch: str, shape_name: str, multi_pod: bool, quant: str = "paper"):
    """Lower + compile one (arch x shape x mesh) cell. Returns result dict."""
    cfg_full = configs.full_config(arch)
    ok, why = SP.cell_supported(cfg_full, shape_name)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    packed = quant.endswith("-packed")
    base_quant = quant.replace("-packed", "")
    if base_quant == "paper":
        qcfg = Q.PAPER
    elif base_quant == "fp":
        qcfg = Q.FP
    else:
        qcfg = Q.QuantConfig(linear=base_quant, nonlinear="BBFP(10,5)")
    if packed:  # weights pre-quantised offline (quant.packed): acts only
        qcfg = Q.QuantConfig(linear=qcfg.linear, nonlinear=qcfg.nonlinear,
                             quantize_weights=False)
    sh = SP.SHAPES[shape_name]
    kind = sh["kind"]
    t0 = time.time()
    long_ctx = sh["batch"] == 1
    act_rules = PT.LONG_RULES if long_ctx else (
        PT.TRAIN_RULES if kind == "train" else PT.SERVE_RULES)

    if kind == "train":
        cfg = cfg_full
        ocfg = O.AdamWConfig()
        step = ST.make_train_step(cfg, ocfg, qcfg, remat=True)
        pshapes = SP.param_specs(cfg)
        state_shapes = jax.eval_shape(
            lambda p: {"params": p, "opt": O.adamw_init(p)}, pshapes)
        psh = S.param_shardings(pshapes, mesh, "train")
        state_sh = {"params": psh,
                    "opt": {"mu": psh, "nu": psh,
                            "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec())}}
        batch_shapes = SP.input_specs(cfg, shape_name)
        bsh = S.batch_shardings(batch_shapes, mesh)
        with PT.activation_sharding(mesh, act_rules):
            lowered = jax.jit(step, in_shardings=(state_sh, bsh),
                              donate_argnums=(0,)).lower(state_shapes, batch_shapes)
    else:
        cfg = SP.serve_config(cfg_full)
        pshapes = SP.param_specs(cfg)
        if packed:
            from repro.core import bbfp as B
            from repro.quant import packed as PK
            fmt = B.parse_format(qcfg.linear)
            pshapes = jax.eval_shape(lambda p: PK.pack_params(p, fmt), pshapes)
        psh = S.param_shardings(pshapes, mesh, "serve")
        batch_shapes = SP.input_specs(cfg, shape_name)
        bsh = S.batch_shardings(batch_shapes, mesh)
        with PT.activation_sharding(mesh, act_rules):
            if kind == "prefill":
                step = ST.make_prefill_step(cfg, qcfg)
                lowered = jax.jit(step, in_shardings=(psh, bsh)).lower(pshapes, batch_shapes)
            else:
                step = ST.make_decode_step(cfg, qcfg)
                cshapes = SP.cache_specs(cfg, shape_name)
                csh = S.cache_shardings(cshapes, mesh)
                lowered = jax.jit(step, in_shardings=(psh, csh, bsh),
                                  donate_argnums=(1,)).lower(pshapes, cshapes, batch_shapes)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    cost = H.analyze(txt, total_devices=n_chips)
    terms = H.roofline_terms(cost, n_chips)
    res = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "quant": quant,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "xla_cost_analysis": {"flops": ca.get("flops"),
                              "bytes": ca.get("bytes accessed")},
        "memory": _mem_analysis(compiled),
        "roofline": terms,
        "hlo_lines": txt.count("\n"),
    }
    return res


def cell_key(arch, shape, meshname, quant):
    return f"{arch}|{shape}|{meshname}|{quant}"


def run_cells(cells, out_path=RESULTS, force=False):
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    results = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    for arch, shape, multi_pod, quant in cells:
        meshname = "multi" if multi_pod else "single"
        key = cell_key(arch, shape, meshname, quant)
        if key in results and results[key].get("status") in ("ok", "skipped") and not force:
            print(f"[cached] {key}")
            continue
        print(f"[lower+compile] {key} ...", flush=True)
        try:
            res = lower_cell(arch, shape, multi_pod, quant)
        except Exception as e:
            traceback.print_exc()
            res = {"status": "error", "error": f"{type(e).__name__}: {e}"}
        results[key] = res
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        if res["status"] == "ok":
            r = res["roofline"]
            print(f"  ok: compile {res['compile_s']}s  "
                  f"compute {r['compute_s']:.2e}s  memory {r['memory_s']:.2e}s  "
                  f"collective {r['collective_s']:.2e}s", flush=True)
        else:
            print(f"  {res['status']}: {res.get('reason', res.get('error',''))}",
                  flush=True)
    return results


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None, choices=list(SP.SHAPES) + [None])
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--quant", default="paper")
    p.add_argument("--all", action="store_true")
    p.add_argument("--force", action="store_true")
    p.add_argument("--out", default=RESULTS)
    args = p.parse_args()

    archs = [a.replace("_", "-") for a in configs.ARCHS if a != "llama7b"] \
        if (args.all or args.arch is None) else [args.arch]
    shapes = list(SP.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multi"]
    cells = [(a, s, m, args.quant) for a in archs for s in shapes for m in meshes]
    run_cells(cells, args.out, args.force)


if __name__ == "__main__":
    main()
