"""Sharding rules: param path -> PartitionSpec.

Train  = FSDP x TP ("ZeRO-3 via GSPMD"): each weight's TP dim is sharded
         over "model" and its other large dim over "data"; SPMD inserts the
         per-layer weight all-gathers. Batch rides ("pod","data"); the pod
         axis is pure DP (params replicated across pods, one gradient
         all-reduce crossing pods per step).
Serve  = TP over "model" only (weights replicated over "data"/"pod";
         batch sharded over ("pod","data")).

MoE expert weights put the expert dim on "model" (expert parallelism; the
dispatch gather/scatter become all-to-alls). Scan-stacked params have a
leading n_layers dim which always stays unsharded (the scan slices it).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# The mesh axis that carries the fused serving path's PAGE-dim KV sharding
# (flash-decoding sequence parallelism). It is deliberately the SAME axis
# as tensor parallelism: a serving mesh stays ("data", "model"), params
# shard over "model" exactly as before, and the fused dispatch re-purposes
# the axis to split the physical page pool instead of the KV heads — so
# head-dim (jnp path) and page-dim (fused path) serving share one mesh and
# one set of committed params. See runtime/paged_kv.shard_paged_cache
# (shard_axis="pages") and kernels/paged_attention.merge_partials.
PAGE_AXIS = "model"


def _axis_size(mesh, name):
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(shape, dim, mesh, axis):
    return shape[dim] % _axis_size(mesh, axis) == 0 and _axis_size(mesh, axis) > 1


# (regex on path, TP dim from the right, FSDP dim from the right)
# dims are negative indices into shape; None = no dim of that kind.
# NOTE path leaves: MoE banks are bare arrays ("ffn/w_gate"); dense layers
# nest a dict ("ffn/w_gate/w").
_RULES = [
    # MoE expert banks: (L, E, d, f) / (L, E, f, d): EP on E, FSDP on d.
    # (/q, /scale: packed serving form — same layout, same specs)
    (re.compile(r"ffn/w_(gate|up)(/(q|scale))?$"),
     {4: (-1, -2), 3: (-1, -2), 2: (-1, -2)}),
    (re.compile(r"ffn/w_down(/(q|scale))?$"),
     {4: (-2, -1), 3: (-2, -1), 2: (-2, -1)}),
    # dense gated MLPs anywhere (decoder ffn, shared experts, griffin, whisper)
    (re.compile(r"w_(gate|up)/(w|q|scale)$"), {3: (-1, -2), 2: (-1, -2)}),
    (re.compile(r"w_down/(w|q|scale)$"), {3: (-2, -1), 2: (-2, -1)}),
    # attention projections
    (re.compile(r"(wq|wk|wv|w_dkv|w_uk|w_uv|in_proj|proj_x|proj_gate|wa|wx)/(w|q|scale)$"),
     {3: (-1, -2), 2: (-1, -2)}),
    (re.compile(r"(wo|out_proj|proj_out)/(w|q|scale)$"), {3: (-2, -1), 2: (-2, -1)}),
    # embeddings: TP on vocab, FSDP on d
    (re.compile(r"embed/w$"), {2: (-2, -1)}),
    (re.compile(r"(lm_head)/w$"), {2: (-1, -2)}),
    (re.compile(r"(enc_pos|dec_pos)/w$"), {2: (None, -1)}),
]

_MOE_EP = re.compile(r"ffn/w_(gate|up|down)(/(q|scale))?$")


def path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_spec(path: str, shape, mesh, mode: str) -> P:
    """mode: 'train' (FSDP x TP) or 'serve' (TP only)."""
    rank = len(shape)
    spec = [None] * rank
    matched = None
    for rex, table in _RULES:
        if rex.search(path) and rank in table:
            matched = table[rank]
            break
    if matched is None:
        # fallback: FSDP-shard the biggest divisible dim in train mode
        if mode == "train" and rank >= 1:
            order = sorted(range(rank), key=lambda i: -shape[i])
            for dim in order:
                if shape[dim] >= 1024 and _fits(shape, dim, mesh, "data"):
                    spec[dim] = "data"
                    break
        return P(*spec)

    tp_dim, fsdp_dim = matched
    is_moe_bank = _MOE_EP.search(path) and rank >= 3
    if is_moe_bank:
        # expert dim = rank-3 (after optional leading L)
        e_dim = rank - 3
        if _fits(shape, e_dim, mesh, "model"):
            spec[e_dim] = "model"
        if mode == "train" and fsdp_dim is not None and _fits(shape, fsdp_dim, mesh, "data"):
            spec[fsdp_dim % rank] = "data"
        return P(*spec)

    if tp_dim is not None and _fits(shape, tp_dim, mesh, "model"):
        spec[tp_dim % rank] = "model"
    if mode == "train" and fsdp_dim is not None:
        d = fsdp_dim % rank
        if spec[d] is None and _fits(shape, d, mesh, "data"):
            spec[d] = "data"
    return P(*spec)


def param_shardings(param_shapes, mesh, mode: str):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStruct."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    out = []
    for path, leaf in flat:
        spec = param_spec(path_str(path), leaf.shape, mesh, mode)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_spec(shape, mesh) -> P:
    """Shard dim0 (global batch) over the batch axes when divisible."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if shape and shape[0] % n == 0 and n > 1:
        return P(axes if len(axes) > 1 else axes[0])
    return P()


def batch_shardings(batch_shapes, mesh):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh)), batch_shapes)


def cache_spec(path: str, shape, mesh, batch_dim: int = 1) -> P:
    """KV/state caches: batch over ("pod","data") when divisible, else the
    time/sequence dim over "data" (long-context, batch=1).

    For GQA k/v caches (..., B, T, KH, hd) the "model" axis goes on KH when
    divisible; otherwise on T (*sequence-parallel KV*): attention then runs
    with sharded keys — per-chip partial scores plus tiny max/sum/output
    all-reduces — instead of resharding the whole cache every layer to chase
    the q-head layout (the 'involuntary full remat' the SPMD partitioner
    warned about; §Perf iteration A)."""
    rank = len(shape)
    spec = [None] * rank
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    if rank > batch_dim and shape[batch_dim] % nb == 0 and nb > 1:
        spec[batch_dim] = baxes if len(baxes) > 1 else baxes[0]
        data_used = True
    else:
        data_used = False

    from repro.perf_flags import enabled
    leaf = path.rsplit("/", 1)[-1]
    is_kv = leaf in ("k", "v") and rank >= batch_dim + 4 and enabled("seqkv_cache")
    t_dim = batch_dim + 1
    if is_kv:
        kh_dim = rank - 2
        if _fits(shape, kh_dim, mesh, "model"):
            spec[kh_dim] = "model"
        elif _fits(shape, t_dim, mesh, "model") and shape[t_dim] >= 2048:
            spec[t_dim] = "model"            # sequence-parallel KV cache
        elif _fits(shape, rank - 1, mesh, "model"):
            spec[rank - 1] = "model"
    else:
        # model axis on a feature dim (from the right, largest divisible)
        for dim in range(rank - 1, batch_dim, -1):
            if spec[dim] is None and _fits(shape, dim, mesh, "model") and shape[dim] >= 16:
                spec[dim] = "model"
                break
    # long-context: put seq on "data" if the batch couldn't use it
    if not data_used and rank > t_dim:
        if spec[t_dim] is None and _fits(shape, t_dim, mesh, "data") and shape[t_dim] >= 4096:
            spec[t_dim] = "data"
        elif spec[t_dim] == "model" and shape[t_dim] % (mesh.shape["model"] * _axis_size(mesh, "data")) == 0:
            spec[t_dim] = ("data", "model")   # 2D sequence-parallel cache
    return P(*spec)


def cache_shardings(cache_shapes, mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in flat:
        ps = path_str(path)
        if leaf.ndim == 0 or ps.endswith("pos"):
            out.append(NamedSharding(mesh, P()))
        else:
            out.append(NamedSharding(mesh, cache_spec(ps, leaf.shape, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)
