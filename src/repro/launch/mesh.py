"""Production meshes. A FUNCTION (not module-level state) so importing this
module never touches jax device initialisation."""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)           # 256 chips (TPU v5e pod slice)
MULTI_POD = (2, 16, 16)         # 2 pods = 512 chips


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.3x; older jax only has the
    # default (auto) behaviour, which is what we want anyway
    if hasattr(jax.sharding, "AxisType"):
        kinds = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=kinds)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — used by tests/examples (1..N CPU
    devices). data axis = all devices, model = 1."""
    n = len(jax.devices())
    return _make_mesh((n, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the global batch."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
