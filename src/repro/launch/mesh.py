"""Production meshes. A FUNCTION (not module-level state) so importing this
module never touches jax device initialisation."""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)           # 256 chips (TPU v5e pod slice)
MULTI_POD = (2, 16, 16)         # 2 pods = 512 chips


def _make_mesh(shape, axes, devices=None):
    # jax.sharding.AxisType landed after 0.4.3x; older jax only has the
    # default (auto) behaviour, which is what we want anyway
    if hasattr(jax.sharding, "AxisType"):
        kinds = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=kinds, devices=devices)
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(tp: int = 1):
    """Whatever this host actually has — used by tests/examples (1..N CPU
    devices), factored as (n // tp, tp) over ("data", "model"). The old
    behaviour hard-coded model=1, which silently swallowed a misconfigured
    serving cell; now `tp` must divide the device count exactly."""
    n = len(jax.devices())
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if n % tp != 0:
        raise ValueError(
            f"tp={tp} does not divide the {n} available device(s); "
            f"a serving cell needs data x model = n, so pick a tp that "
            f"divides the device count (or force one with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return _make_mesh((n // tp, tp), ("data", "model"))


def make_serving_mesh(tp: int = 1, dp: int = 1):
    """One serving cell: a (dp, tp) mesh over ("data", "model") using the
    FIRST dp*tp devices — unlike make_host_mesh it does not have to consume
    the whole host, so several cells (data-parallel engine replicas) can
    partition one machine's devices. `tp` shards the ModelRunner's compiled
    shapes (params + head-sharded page pools); `dp` is batch sharding
    WITHIN one engine replica (distinct from the EngineFleet's replica-
    level data parallelism, which runs whole separate engines).

    The "model" axis is dual-use: the jnp paged path head-shards the KV
    pools over it, while the fused Pallas path page-shards them over the
    SAME axis (flash-decoding sequence parallelism, ``sharding.PAGE_AXIS``)
    — one mesh serves both dispatches, and params stay TP-sharded either
    way."""
    if tp < 1 or dp < 1:
        raise ValueError(f"tp and dp must be >= 1, got tp={tp} dp={dp}")
    devices = jax.devices()
    if tp * dp > len(devices):
        raise ValueError(
            f"serving mesh tp={tp} x dp={dp} needs {tp * dp} devices but "
            f"only {len(devices)} are available (force more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return _make_mesh((dp, tp), ("data", "model"),
                      devices=devices[:dp * tp])


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the global batch."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    """Size of mesh axis `name`; 1 for mesh=None or an absent axis, so
    callers can branch on "is this dimension actually split" without
    special-casing unmeshed runs."""
    if mesh is None or name not in getattr(mesh, "axis_names", ()):
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]
