"""Input shape cells: ShapeDtypeStruct stand-ins for every (arch x shape).

Shapes (assigned, LM family — seq_len x global_batch):
  train_4k     4,096 x 256   (training:   train_step)
  prefill_32k  32,768 x 32   (inference:  prefill_step)
  decode_32k   32,768 x 128  (decode:     serve_step, KV cache of seq_len)
  long_500k    524,288 x 1   (long decode; SSM/hybrid/local-attn only)

The skip table lives in DESIGN.md §6 and is enforced by `cell_supported`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import common as C
from repro.models import model as M

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# archs allowed to run long_500k (sub-quadratic / bounded-window attention)
LONG_OK = {"mamba2-2.7b", "recurrentgemma-2b", "gemma3-4b"}


def cell_supported(cfg: C.ArchConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.name not in LONG_OK:
        return False, "pure full attention — long_500k skipped (DESIGN.md §6)"
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: C.ArchConfig, shape_name: str) -> dict:
    """ShapeDtypeStructs for the step's *data* inputs (params/cache handled
    separately by the dry-run via eval_shape)."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    kind = sh["kind"]
    if kind == "train":
        batch = {"tokens": sds((b, s), jnp.int32), "labels": sds((b, s), jnp.int32)}
        if cfg.vis_len:
            batch["vis_embed"] = sds((b, cfg.vis_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "whisper":
            batch["frames"] = sds((b, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
        return batch
    if kind == "prefill":
        batch = {"tokens": sds((b, s), jnp.int32)}
        if cfg.vis_len:
            batch["vis_embed"] = sds((b, cfg.vis_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "whisper":
            batch["frames"] = sds((b, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a cache of capacity s
    return {"tokens": sds((b, 1), jnp.int32)}


def cache_specs(cfg: C.ArchConfig, shape_name: str):
    """eval_shape of init_cache with pos=seq-1 semantics."""
    sh = SHAPES[shape_name]
    return jax.eval_shape(lambda: M.init_cache(cfg, sh["batch"], sh["seq"]))


def param_specs(cfg: C.ArchConfig):
    return jax.eval_shape(
        lambda k: M.init(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))


def serve_config(cfg: C.ArchConfig) -> C.ArchConfig:
    """bf16 weights on the serving path."""
    return dataclasses.replace(cfg, param_dtype=jnp.bfloat16)
