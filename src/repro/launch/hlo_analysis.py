"""Post-SPMD HLO text analyzer for the roofline terms.

XLA's compiled.cost_analysis() visits each instruction ONCE — a scan-over-80-
layers model reports 1/80th of the real FLOPs (verified). This module parses
``compiled.as_text()`` and rolls the call graph up properly:

  * dot FLOPs = 2 * numel(result) * prod(contracting dims)  (per instruction)
  * elementwise/reduce FLOPs ~= numel(result)
  * while bodies multiply by the trip count recovered from the loop-condition
    constant (scan emits `compare(counter, constant(N)), direction=LT`)
  * fusions contribute their interior FLOPs but only their *boundary* bytes
    (fused interiors never touch HBM)
  * collective bytes follow ring-algorithm wire-cost conventions:
      all-reduce 2*s*(n-1)/n | all-gather / reduce-scatter / all-to-all
      s*(n-1)/n | collective-permute s,   with n = replica-group size.

Outputs feed EXPERIMENTS.md §Roofline directly.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS_RE = re.compile(r"calls=(%?[\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=(%?[\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=(%?[\w\.\-]+), body=(%?[\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "floor",
    "ceil", "round-nearest-even", "round-nearest-afz", "sign", "compare",
    "select", "and", "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "clamp", "convert", "exponential-minus-one",
    "log-plus-one", "logistic", "reduce", "reduce-window", "cbrt", "atan2",
    "remainder",
}
_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "reshape", "broadcast",
}
# copy/transpose DO move bytes in a partitioned program (SPMD resharding
# materialises them); costed as read+write of the result.
_MOVE_OPS = {"copy", "copy-start", "copy-done", "transpose"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def _shape_bytes(text: str) -> int:
    """Sum bytes over every shape literal in `text`."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_numel_bytes(result: str) -> tuple[int, int]:
    numel, byt = 0, 0
    for dt, dims in _SHAPE_RE.findall(result):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel += n
        byt += n * _DTYPE_BYTES[dt]
    return numel, byt


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, f):
        return Cost(self.flops * f, self.bytes * f, self.coll_bytes * f,
                    {k: v * f for k, v in self.coll_by_kind.items()})


# result type is either a tuple "(f32[..], /*index=5*/ s32[..], ...)" (no
# nested parens; may contain "=" inside /*index=N*/ comments) or a bare shape.
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len(m.group(1).split(",")))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota v2 format [ngroups,group_size]
        return max(1, int(m.group(2)))
    return default


def parse_computations(hlo_text: str) -> dict:
    """name -> list of instruction lines."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?[^{]*\{\s*$", line)
        if m and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and "=" in line:
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Loop bound from the condition computation: the constant compared
    against the induction variable."""
    cands = []
    for ln in cond_lines:
        for c in _CONST_RE.findall(ln):
            v = int(c)
            if 1 <= v <= 10**7:
                cands.append(v)
    return max(cands) if cands else 1


def analyze(hlo_text: str, total_devices: int = 1, on_cost=None) -> Cost:
    """on_cost(op_label, result_str, Cost, multiplier) is called per
    instruction when provided (hlo_census builds its buckets from it)."""
    comps = parse_computations(hlo_text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    if m:
        entry = m.group(1)
    else:  # fall back to last computation
        entry = list(comps)[-1]

    # name -> result-shape string, per computation (operands are printed
    # without shapes in modern HLO text)
    shapes: dict[str, dict[str, str]] = {}
    for cname, lines in comps.items():
        d = {}
        for line in lines:
            mi = _INSTR_RE.match(line)
            if mi:
                d[mi.group(1)] = mi.group(2)
        # parameters are declared in the computation signature; recover them
        shapes[cname] = d

    memo: dict[str, Cost] = {}

    def operand_info(cname: str, line: str) -> list:
        """(bytes, numel) of each named operand."""
        body = line.split("(", 1)[1] if "(" in line else ""
        body = body.split("), ")[0]
        out = []
        for nm in _OPERAND_RE.findall(body):
            s = shapes[cname].get(nm)
            if s:
                n, b = _result_numel_bytes(s)
                out.append((b, n))
        return out

    def operand_bytes(cname: str, line: str) -> int:
        return sum(b for b, _ in operand_info(cname, line))

    def comp_cost(name: str, depth=0) -> Cost:
        name = name.lstrip("%")
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # break cycles defensively
        total = Cost()
        for line in comps.get(name, []):
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            _nm, result, op = mi.group(1), mi.group(2), mi.group(3)
            numel, rbytes = _result_numel_bytes(result)
            if op == "while":
                mw = _WHILE_RE.search(line)
                if mw:
                    cond, body = mw.group(1), mw.group(2)
                    trips = _trip_count(comps.get(cond.lstrip("%"), []))
                    total += comp_cost(body, depth + 1).scaled(trips)
                continue
            if op == "conditional":
                mb = _BRANCHES_RE.search(line)
                if mb:
                    branch_costs = [comp_cost(b.strip(), depth + 1)
                                    for b in mb.group(1).split(",")]
                    if branch_costs:
                        total += max(branch_costs, key=lambda c: c.flops)
                continue
            if op in ("call", "async-start"):
                mc = _TO_APPLY_RE.search(line) or _CALLS_RE.search(line)
                if mc:
                    total += comp_cost(mc.group(1), depth + 1)
                continue
            if op == "fusion":
                mc = _CALLS_RE.search(line)
                if mc:
                    inner = comp_cost(mc.group(1), depth + 1)
                    # interior flops count; interior bytes don't touch HBM
                    total += Cost(flops=inner.flops,
                                  coll_bytes=inner.coll_bytes,
                                  coll_by_kind=dict(inner.coll_by_kind))
                ob_list = operand_info(name, line)
                ob = sum(b for b, _ in ob_list)
                # in-place update pattern: a LARGE operand aliases the result
                # numel-wise (scan-carried cache/weight buffers); only the
                # delta moves. Guard: the aliased operand must dominate the
                # fusion (>=8x the rest) so ordinary elementwise fusions
                # keep the full boundary cost.
                aliased = [b for b, n in ob_list if n == numel and n > 0]
                rest = ob - (max(aliased) if aliased else 0)
                if aliased and rest * 8 <= max(aliased):
                    total += Cost(bytes=2.0 * rest + min(rbytes, 4 * rest))
                else:
                    total += Cost(bytes=rbytes + ob)
                continue
            if op in _ZERO_COST:
                continue
            if op in _MOVE_OPS:
                total += Cost(bytes=2.0 * rbytes)
                continue
            base = op.replace("-start", "") if op.endswith("-start") else op
            if base in _COLLECTIVES or base in ("all-reduce", "all-gather",
                                                "reduce-scatter", "all-to-all",
                                                "collective-permute"):
                n = _group_size(line, total_devices)
                # wire bytes per participating device (ring conventions)
                if base.startswith("all-reduce"):
                    wire = 2.0 * rbytes * (n - 1) / max(n, 1)
                elif base == "collective-permute":
                    wire = float(rbytes)
                else:
                    wire = float(rbytes) * (n - 1) / max(n, 1)
                total += Cost(bytes=rbytes * 2.0, coll_bytes=wire,
                              coll_by_kind={base: wire})
                continue
            if op == "dynamic-update-slice":
                # in-place update: traffic = read+write of the UPDATE region,
                # not the whole buffer (XLA aliases the result).
                body = line.split("(", 1)[1]
                ops_ = _OPERAND_RE.findall(body)
                upd = shapes[name].get(ops_[1]) if len(ops_) > 1 else None
                ub = _shape_bytes(upd) if upd else rbytes
                total += Cost(bytes=2.0 * ub)
                continue
            if op in ("dynamic-slice", "gather"):
                # reads only the sliced/gathered region, not the operand
                total += Cost(bytes=2.0 * rbytes)
                continue
            if op == "scatter":
                # in-place: traffic ~ the non-buffer operands (indices+updates)
                ob_list = [b for b, _ in operand_info(name, line)]
                total += Cost(bytes=2.0 * (sum(ob_list) - max(ob_list))
                              if ob_list else float(rbytes))
                continue
            op_bytes = rbytes + operand_bytes(name, line)
            if op in ("dot", "dot-general"):
                k = _dot_contract_size(name, line, shapes)
                total += Cost(flops=2.0 * numel * k, bytes=op_bytes)
            elif op == "convolution":
                k = _conv_kernel_size(line)
                total += Cost(flops=2.0 * numel * k, bytes=op_bytes)
            elif base in _ELEMENTWISE:
                total += Cost(flops=float(numel), bytes=op_bytes)
            else:
                total += Cost(bytes=op_bytes)
        memo[name] = total
        return total

    return comp_cost(entry)


def _dot_contract_size(cname: str, line: str, shapes) -> int:
    """prod of lhs contracting dims (lhs shape looked up by operand name)."""
    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if not mdims:
        return 1
    # first operand name inside dot(...)
    body = line.split("dot(", 1)[-1]
    ops = _OPERAND_RE.findall(body)
    shape_str = None
    if ops:
        shape_str = shapes[cname].get(ops[0])
    if shape_str is None:
        m = re.search(r"dot\(\s*(\w+\[[\d,]*\])", line)  # legacy typed form
        shape_str = m.group(1) if m else None
    if shape_str is None:
        return 1
    found = _SHAPE_RE.findall(shape_str)
    if not found:
        return 1
    _, dims = found[0]
    shape = [int(d) for d in dims.split(",") if d]
    k = 1
    for i in (int(x) for x in mdims.group(1).split(",") if x):
        if i < len(shape):
            k *= shape[i]
    return k


def _conv_kernel_size(line: str) -> int:
    shapes = _SHAPE_RE.findall(line.split("convolution(")[-1])
    if len(shapes) >= 2:
        _, dims = shapes[1]
        k = 1
        for d in dims.split(","):
            if d:
                k *= int(d)
        return max(1, k // 1)
    return 1


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9  # ~50 GB/s/link


def roofline_terms(cost: Cost, n_chips: int) -> dict:
    """The three §Roofline terms, in seconds. `cost` is whole-program
    (per-replica SPMD program == per-chip work for flops/bytes; coll_bytes is
    already per-device wire bytes)."""
    return {
        "compute_s": cost.flops / PEAK_FLOPS_BF16,
        "memory_s": cost.bytes / HBM_BW,
        "collective_s": cost.coll_bytes / ICI_BW_PER_LINK,
        "flops": cost.flops,
        "bytes": cost.bytes,
        "coll_bytes": cost.coll_bytes,
        "coll_by_kind": dict(cost.coll_by_kind),
    }
