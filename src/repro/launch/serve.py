"""Serving launcher: batched prefill + decode with the BBFP serving stack
(BBFP linears via fake-quant or the Pallas kernel path, LUT nonlinear unit).

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --quant "BBFP(4,2)"

Continuous-batching mode (ragged prompts through the paged-KV scheduler;
--page-size/--n-pages set the page geometry and pool budget, --kv-layout
dense falls back to the slab cache, --kv-storage packed keeps KV pages as
int8 codes + shared exponents — ~2x fewer KV bytes at BBFP(6,3)):

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --continuous --batch 8 --slots 4 --max-len 128 --page-size 32 \
      --kv-storage packed

Shared-system-prompt workload: --shared-prefix P prepends the same P random
tokens to every request, so the prefix cache maps the common pages into
each follower's block table (stored once, prefill skipped) and chunked
prefill only runs the unique remainders; --no-prefix-cache re-stores and
recomputes everything, --prefill-chunk sets the fixed prefill step width:

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --continuous --batch 8 --slots 4 --max-len 256 --shared-prefix 96

Preemption (--preempt; paged layout only): admission reserves only the
prompt's pages, so the page pool may be OVERSUBSCRIBED — when a decode
append or a higher-priority admission finds it exhausted, the lowest-
priority running sequence is evicted and requeued for recompute-on-
readmit (token-identical under greedy decode). --preempt-demo runs a
canned oversubscribed mixed-length workload and prints the preemption /
recompute counters:

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --preempt-demo --slots 4 --batch 6
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models import partitioning as PT
from repro.quant import linear as Q


def generate(cfg, params, prompts, qcfg, gen_len: int, extras=None):
    """Greedy batched generation. prompts: (B, P) int32.

    Decoder-family caches carry a per-slot position vector cache["pos"]
    (B,), so the single jitted decode below would serve rows at different
    lengths too — ragged admission/retirement lives in
    repro.runtime.batcher.ContinuousBatcher; this helper is the dense
    same-length case (and the batcher's sequential reference)."""
    extras = extras or {}
    b, p_len = prompts.shape
    max_len = p_len + gen_len + (cfg.vis_len or 0)
    logits, cache = M.prefill(params, cfg, prompts, qcfg, max_len=max_len, **extras)
    pos = jnp.asarray(cache["pos"])
    if pos.ndim:
        # dense same-length batch: collapse the per-slot pos vector to a
        # scalar so decode keeps the contiguous cache-write fast path
        cache = {**cache, "pos": pos[0]}
    decode = jax.jit(lambda pr, c, t: M.decode_step(pr, cfg, c, t, qcfg))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    for _ in range(gen_len - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama7b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--quant", default="BBFP(4,2)")
    p.add_argument("--nonlinear", default="BBFP(10,5)")
    p.add_argument("--seed", type=int, default=0)
    # continuous-batching / paged-KV serving mode
    p.add_argument("--continuous", action="store_true",
                   help="serve ragged requests through ContinuousBatcher")
    p.add_argument("--slots", type=int, default=4,
                   help="decode slots in the continuous batcher")
    p.add_argument("--max-len", type=int, default=128,
                   help="per-request KV capacity (prompt + max_new - 1)")
    p.add_argument("--kv-layout", choices=["paged", "dense"], default="paged")
    p.add_argument("--kv-storage", choices=["fp", "packed"], default="fp",
                   help="paged page storage: bf16 values, or packed int8 "
                        "codes + shared exponents (~2x fewer KV bytes)")
    p.add_argument("--kv-quant", default=None,
                   help="KV-cache quantisation format (default: none; "
                        "--kv-storage packed defaults it to BBFP(6,3))")
    p.add_argument("--page-size", type=int, default=32,
                   help="KV rows per page (32 = BBFP quantisation block)")
    p.add_argument("--n-pages", type=int, default=None,
                   help="page pool budget (default: slots * max_len/page)")
    p.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="share page-aligned prompt prefixes across requests "
                        "(copy-on-write pages; paged layout only)")
    p.add_argument("--prefill-chunk", type=int, default=32,
                   help="incremental chunked-prefill step width (paged "
                        "layout; ONE compiled prefill shape)")
    p.add_argument("--shared-prefix", type=int, default=0,
                   help="prepend this many common tokens to every request "
                        "(shared-system-prompt workload for the prefix cache)")
    p.add_argument("--prefill-slots", type=int, default=None,
                   help="admissions per batched chunk-prefill call "
                        "(default: --slots; ONE compiled prefill shape)")
    p.add_argument("--preempt", action="store_true",
                   help="oversubscribe the page pool: evict the lowest-"
                        "priority running sequence when it runs out and "
                        "recompute it on readmission (paged layout only)")
    p.add_argument("--preempt-demo", action="store_true",
                   help="canned oversubscribed mixed-length workload; "
                        "implies --continuous --preempt and prints the "
                        "preemption/recompute counters")
    args = p.parse_args(argv)

    if args.preempt_demo:
        args.continuous = args.preempt = True
    if args.preempt and not args.continuous:
        # preemption is a property of the ContinuousBatcher's page pool;
        # the plain generate path has no pool to oversubscribe
        p.error("--preempt requires --continuous")
    if args.preempt and args.kv_layout == "dense":
        # the dense slab reserves a full (max_len) row range per slot up
        # front — there are no pages to evict, so the flag would be a no-op
        # that silently changes nothing; reject it like --kv-storage packed
        p.error("--preempt requires --kv-layout paged "
                "(the dense slab has no pages to evict)")
    if args.kv_storage == "packed" and not args.continuous:
        # packed pages live in the ContinuousBatcher's paged pool; the plain
        # generate path has no packed store, and silently enabling KV
        # fake-quant there would change tokens while packing nothing
        p.error("--kv-storage packed requires --continuous")
    cfg = configs.smoke_config(args.arch) if args.smoke else configs.full_config(args.arch)
    kv_quant = args.kv_quant
    if kv_quant is None:
        # packed pages need a storage format; BBFP(6,3) is the serving
        # default (8.16-bit class, near-lossless KV)
        kv_quant = "BBFP(6,3)" if args.kv_storage == "packed" else "none"
    elif kv_quant.lower() == "none" and args.kv_storage == "packed":
        p.error("--kv-storage packed needs a KV format (--kv-quant), "
                "it is the page storage format")
    qcfg = Q.QuantConfig(linear=args.quant, nonlinear=args.nonlinear,
                         kv_cache=kv_quant)
    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    extras = {}
    if cfg.vis_len:
        extras["vis_embed"] = jax.random.normal(
            key, (args.batch, cfg.vis_len, cfg.d_model)) * 0.1
    if cfg.family == "whisper":
        extras["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder.n_frames, cfg.d_model)) * 0.1

    mesh = make_host_mesh()
    if args.continuous:
        from repro.runtime.batcher import ContinuousBatcher, Request
        assert cfg.family == "decoder", "continuous mode targets decoders"
        gen = args.gen
        if args.preempt_demo:
            # oversubscribed pool, mixed lengths: every request fits the
            # pool ALONE, the concurrent mix does not — admission fills the
            # pool with prompt pages and the first decode page-boundary
            # crossings force preemptions + recompute-on-readmit
            args.shared_prefix = args.shared_prefix or args.page_size
            gen = max(gen, args.page_size)
            p_lens = [args.page_size + 9 + (7 * i) % 17
                      for i in range(args.batch)]
            if args.n_pages is None:
                args.n_pages = 2 * args.slots   # prompt pages only: tight
        else:
            p_lens = [max(1, args.prompt_len - 4 + (3 * i) % 9)
                      for i in range(args.batch)]
        bat = ContinuousBatcher(cfg, params, qcfg, n_slots=args.slots,
                                max_len=args.max_len,
                                kv_layout=args.kv_layout,
                                kv_storage=args.kv_storage,
                                page_size=args.page_size,
                                n_pages=args.n_pages,
                                prefix_cache=args.prefix_cache,
                                prefill_chunk=args.prefill_chunk,
                                prefill_slots=args.prefill_slots,
                                preempt=args.preempt)
        shared = jax.random.randint(jax.random.fold_in(key, 999),
                                    (args.shared_prefix,), 0, cfg.vocab)
        for i, p_len in enumerate(p_lens):   # ragged mix
            prompt = jax.random.randint(jax.random.fold_in(key, i),
                                        (p_len,), 0, cfg.vocab)
            if args.shared_prefix:    # shared-system-prompt workload
                prompt = jnp.concatenate([shared, prompt])
            bat.submit(Request(rid=i, prompt=prompt, max_new=gen))
        with PT.activation_sharding(mesh, PT.SERVE_RULES):
            t0 = time.perf_counter()
            finished, ticks = bat.run()
            dt = time.perf_counter() - t0
        n_new = sum(len(r.out_tokens) for r in finished)
        stats = bat.kv_stats()
        print(f"arch={cfg.name} quant={qcfg.linear}/{qcfg.nonlinear} "
              f"layout={stats['kv_layout']} storage={stats['kv_storage']}")
        print(f"served {len(finished)} requests / {n_new} tokens in "
              f"{dt:.2f}s over {ticks} ticks ({bat.decode_calls} decode "
              f"calls, {bat.prefill_traces} prefill traces, "
              f"{bat.chunk_prefill_calls} prefill chunks in "
              f"{bat.prefill_steps} batched steps)")
        if bat.paged:
            print(f"prefix cache: hit rate {bat.prefix_hit_rate:.0%} "
                  f"({bat.prefix_hit_pages} of "
                  f"{bat.prefix_hit_pages + bat.prefix_miss_pages} prompt "
                  f"pages served from resident pages; radix index "
                  f"{stats['radix_pages']} pages)")
        if args.preempt:
            done = sum(len(r.out_tokens) == gen for r in finished)
            print(f"preemption: pool {stats['pages_total']} pages for "
                  f"{len(p_lens)} requests -> {stats['preemptions']} "
                  f"preemptions, {stats['recomputed_tokens']} tokens "
                  f"recomputed on readmit, {done}/{len(p_lens)} requests "
                  f"ran to full budget")
        print("kv:", {k: v for k, v in stats.items() if k != "kv_layout"})
        return finished
    with PT.activation_sharding(mesh, PT.SERVE_RULES):
        t0 = time.perf_counter()
        tokens = generate(cfg, params, prompts, qcfg, args.gen, extras)
        jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
    n_new = args.batch * args.gen
    print(f"arch={cfg.name} quant={qcfg.linear}/{qcfg.nonlinear}")
    print(f"generated {n_new} tokens in {dt:.2f}s  ({n_new/dt:.1f} tok/s)")
    print("sample:", tokens[0, :16].tolist())
    return tokens


if __name__ == "__main__":
    main()
