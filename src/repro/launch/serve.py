"""Serving launcher: batched prefill + decode with the BBFP serving stack
(BBFP linears via fake-quant or the Pallas kernel path, LUT nonlinear unit).

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --quant "BBFP(4,2)"

Continuous-batching mode (ragged prompts through the paged-KV scheduler;
--page-size/--n-pages set the page geometry and pool budget, --kv-layout
dense falls back to the slab cache, --kv-storage packed keeps KV pages as
int8 codes + shared exponents — ~2x fewer KV bytes at BBFP(6,3)):

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --continuous --batch 8 --slots 4 --max-len 128 --page-size 32 \
      --kv-storage packed

Fused paged attention (--paged-attn fused; packed/packed4 storage only)
runs decode + chunk-prefill attention as ONE Pallas kernel per layer —
page gather, in-VMEM BBFP dequant, flash online softmax — instead of the
gather/dequant/attend jnp ops; --kv-storage packed4 stores two nibble
codes per byte (~4.25 bits/elt, ~4x fewer KV bytes than bf16) and
requires the fused kernel:

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --continuous --batch 8 --slots 4 --kv-storage packed4 \
      --paged-attn fused

Shared-system-prompt workload: --shared-prefix P prepends the same P random
tokens to every request, so the prefix cache maps the common pages into
each follower's block table (stored once, prefill skipped) and chunked
prefill only runs the unique remainders; --no-prefix-cache re-stores and
recomputes everything, --prefill-chunk sets the fixed prefill step width:

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --continuous --batch 8 --slots 4 --max-len 256 --shared-prefix 96

Preemption (--preempt; paged layout only): admission reserves only the
prompt's pages, so the page pool may be OVERSUBSCRIBED — when a decode
append or a higher-priority admission finds it exhausted, the lowest-
priority running sequence is evicted and requeued for recompute-on-
readmit (token-identical under greedy decode). --preempt-demo runs a
canned oversubscribed mixed-length workload and prints the preemption /
recompute counters:

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --preempt-demo --slots 4 --batch 6

Async serving front door (--serve; implies --continuous, paged layout
only): requests arrive through the asyncio server in launch/server.py at
a seeded Poisson rate (--rate req/s), stream their tokens back as they
decode, and the engine runs the OVERLAPPED loop — host scheduling/radix
work for tick N+1 while tick N's decode is in flight, blocking only at
the stream edge. --serve-slo assigns SLO classes (mapped onto scheduler
priority), --deadline-ms sets the per-request latency budget that the
goodput accounting checks. Prints TTFT/TPOT p50/p95, goodput, and the
overlap counters:

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --serve --batch 8 --slots 4 --rate 16 --deadline-ms 60000

Fault tolerance (--serve only): --chaos-seed injects deterministic
retryable tick failures (seeded, retry-exact), --chaos-kill-tick kills
replica 0 at that tick (with --replicas > 1 in-flight requests fail
over to survivors and replay token-identically), --request-timeout-s
cancels overdue streams and frees their pages, --shed-policy rejects
batch-class requests under overload. --kv-snapshot DIR persists the
radix index + packed pages after the run and warm-restores them before
it (paged layout; works in --continuous and --serve modes):

  PYTHONPATH=src python -m repro.launch.serve --arch llama7b --smoke \
      --serve --replicas 2 --chaos-kill-tick 3 --request-timeout-s 60
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh, make_serving_mesh
from repro.models import model as M
from repro.models import partitioning as PT
from repro.quant import linear as Q


def generate(cfg, params, prompts, qcfg, gen_len: int, extras=None):
    """Greedy batched generation. prompts: (B, P) int32.

    Decoder-family caches carry a per-slot position vector cache["pos"]
    (B,), so the single jitted decode below would serve rows at different
    lengths too — ragged admission/retirement lives in
    repro.runtime.batcher.ContinuousBatcher; this helper is the dense
    same-length case (and the batcher's sequential reference)."""
    extras = extras or {}
    b, p_len = prompts.shape
    max_len = p_len + gen_len + (cfg.vis_len or 0)
    logits, cache = M.prefill(params, cfg, prompts, qcfg, max_len=max_len, **extras)
    pos = jnp.asarray(cache["pos"])
    if pos.ndim:
        # dense same-length batch: collapse the per-slot pos vector to a
        # scalar so decode keeps the contiguous cache-write fast path
        cache = {**cache, "pos": pos[0]}
    decode = jax.jit(lambda pr, c, t: M.decode_step(pr, cfg, c, t, qcfg))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    for _ in range(gen_len - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def _serve_async(args, bats, prompts, gen: int, mesh):
    """--serve mode: run the asyncio front door over the overlapped engine
    loop with seeded Poisson arrivals; print latency percentiles, goodput,
    and the overlap counters. `bats` is one batcher per engine replica —
    more than one puts the EngineFleet router in front."""
    import asyncio

    from repro.launch.router import EngineFleet
    from repro.launch.server import (
        AsyncServer, WorkItem, closed_loop, percentile_rows,
    )
    from repro.runtime.faults import ChaosInjector

    slos = ["interactive", "standard", "batch"]
    slo = args.serve_slo or "mix"
    work = [WorkItem(prompt=p, max_new=gen,
                     slo=slos[i % 3] if slo == "mix" else slo,
                     deadline_s=args.deadline_ms / 1e3
                     if args.deadline_ms is not None else None)
            for i, p in enumerate(prompts)]
    rate = args.rate if args.rate is not None else 8.0
    chaos_on = (args.chaos_seed is not None
                or args.chaos_kill_tick is not None)

    def chaos_for(i):
        # chaos targets replica 0 only, so with --replicas > 1 the
        # survivors absorb the failover instead of the whole fleet dying
        if not chaos_on or i > 0:
            return None
        return ChaosInjector(
            seed=args.chaos_seed or 0,
            # a bare --chaos-kill-tick is a clean kill; a seed adds
            # retryable tick failures at a fixed deterministic rate
            tick_fail_rate=0.1 if args.chaos_seed is not None else 0.0,
            kill_at_tick=args.chaos_kill_tick)

    async def go():
        servers = [AsyncServer(b, chaos=chaos_for(i),
                               request_timeout_s=args.request_timeout_s,
                               shed_policy=args.shed_policy or "none",
                               shed_depth=args.shed_depth)
                   for i, b in enumerate(bats)]
        if len(servers) == 1:
            srv = servers[0]
        else:
            srv = EngineFleet(servers, routing=args.routing or "prefix",
                              page=args.page_size,
                              spill_threshold=2 * args.slots,
                              seed=args.seed)
        await srv.start()
        mets = await closed_loop(srv, work, rate=rate, seed=args.seed)
        await srv.shutdown(drain=True)
        return srv, mets

    with PT.activation_sharding(mesh, PT.SERVE_RULES):
        t0 = time.perf_counter()
        srv, mets = asyncio.run(go())
        dt = time.perf_counter() - t0
    n_new = sum(m.n_tokens for m in mets)
    pr = percentile_rows(mets)
    ctr = srv.counters()
    print(f"arch={bats[0].cfg.name} serve=async rate={rate}/s slo={slo} "
          f"requests={len(work)} tp={args.tp or 1} replicas={len(bats)}")
    if len(bats) > 1:
        print(f"fleet: routing={ctr['routing']} picks={ctr['picks']} "
              f"spills={ctr['spills']} affinity hit rate "
              f"{ctr['fleet_affinity_hit_rate']:.0%}")
    print(f"served {len(mets)} streams / {n_new} tokens in {dt:.2f}s "
          f"({ctr['decode_calls']} decode calls)")
    print(f"ttft p50/p95 = {pr['ttft_p50_us'] / 1e3:.1f}/"
          f"{pr['ttft_p95_us'] / 1e3:.1f} ms   "
          f"tpot p50/p95 = {pr['tpot_p50_us'] / 1e3:.2f}/"
          f"{pr['tpot_p95_us'] / 1e3:.2f} ms   "
          f"goodput = {pr['goodput_rps']:.2f} req/s "
          f"({pr['good']}/{pr['of']} in deadline)")
    print(f"overlap: {ctr['overlapped_ticks']} overlapped ticks, "
          f"{ctr['host_idle_ticks']} host-idle ticks, "
          f"{ctr['preemptions']} preemptions")
    if (chaos_on or args.request_timeout_s is not None
            or (args.shed_policy or "none") != "none"):
        print(f"faults: {ctr['tick_failures']} tick failures, "
              f"{ctr.get('failovers', 0)} failovers, "
              f"{ctr['shed']} shed, {ctr['timeouts']} timeouts, "
              f"health={ctr['health']}")
    return mets


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama7b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--quant", default="BBFP(4,2)")
    p.add_argument("--nonlinear", default="BBFP(10,5)")
    p.add_argument("--seed", type=int, default=0)
    # continuous-batching / paged-KV serving mode
    p.add_argument("--continuous", action="store_true",
                   help="serve ragged requests through ContinuousBatcher")
    p.add_argument("--slots", type=int, default=4,
                   help="decode slots in the continuous batcher")
    p.add_argument("--max-len", type=int, default=128,
                   help="per-request KV capacity (prompt + max_new - 1)")
    p.add_argument("--kv-layout", choices=["paged", "dense"], default="paged")
    p.add_argument("--kv-storage", choices=["fp", "packed", "packed4"],
                   default="fp",
                   help="paged page storage: bf16 values, packed int8 "
                        "codes + shared exponents (~2x fewer KV bytes), or "
                        "packed4 nibble codes — two per byte, ~4x fewer "
                        "(requires --paged-attn fused)")
    p.add_argument("--paged-attn", choices=["fused", "unfused"],
                   default="unfused",
                   help="packed paged decode attention: 'fused' runs the "
                        "Pallas kernel (page gather + BBFP dequant + flash "
                        "softmax in one VMEM pass), 'unfused' the gathered-"
                        "dequant jnp path (default)")
    p.add_argument("--kv-quant", default=None,
                   help="KV-cache quantisation format (default: none; "
                        "--kv-storage packed defaults it to BBFP(6,3))")
    p.add_argument("--page-size", type=int, default=32,
                   help="KV rows per page (32 = BBFP quantisation block)")
    p.add_argument("--n-pages", type=int, default=None,
                   help="page pool budget (default: slots * max_len/page)")
    p.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="share page-aligned prompt prefixes across requests "
                        "(copy-on-write pages; paged layout only)")
    p.add_argument("--prefill-chunk", type=int, default=32,
                   help="incremental chunked-prefill step width (paged "
                        "layout; ONE compiled prefill shape)")
    p.add_argument("--shared-prefix", type=int, default=0,
                   help="prepend this many common tokens to every request "
                        "(shared-system-prompt workload for the prefix cache)")
    p.add_argument("--prefill-slots", type=int, default=None,
                   help="admissions per batched chunk-prefill call "
                        "(default: --slots; ONE compiled prefill shape)")
    p.add_argument("--preempt", action="store_true",
                   help="oversubscribe the page pool: evict the lowest-"
                        "priority running sequence when it runs out and "
                        "recompute it on readmission (paged layout only)")
    p.add_argument("--preempt-demo", action="store_true",
                   help="canned oversubscribed mixed-length workload; "
                        "implies --continuous --preempt and prints the "
                        "preemption/recompute counters")
    # async front-door mode (launch/server.py)
    p.add_argument("--serve", action="store_true",
                   help="run the asyncio streaming front door over the "
                        "overlapped engine loop (implies --continuous; "
                        "paged layout only)")
    p.add_argument("--rate", type=float, default=None,
                   help="Poisson arrival rate in requests/s for --serve "
                        "(default 8.0; seeded, deterministic schedule)")
    p.add_argument("--deadline-ms", type=float, default=None,
                   help="per-request end-to-end deadline for --serve's "
                        "goodput accounting (default: none)")
    p.add_argument("--serve-slo",
                   choices=["interactive", "standard", "batch", "mix"],
                   default=None,
                   help="SLO class for --serve requests (mapped onto the "
                        "scheduler's priority field); 'mix' round-robins "
                        "the three classes (default)")
    # fault tolerance (runtime/faults.py + launch/server.py supervision)
    p.add_argument("--chaos-seed", type=int, default=None,
                   help="inject deterministic retryable tick failures "
                        "keyed on (seed, tick) (--serve only; the tick "
                        "retry replays them token-identically)")
    p.add_argument("--chaos-kill-tick", type=int, default=None,
                   help="kill replica 0's engine at this tick (--serve "
                        "only; with --replicas > 1 its in-flight requests "
                        "fail over to the survivors)")
    p.add_argument("--request-timeout-s", type=float, default=None,
                   help="per-request wall-clock budget: overdue streams "
                        "are cancelled and their pages freed (--serve only)")
    p.add_argument("--shed-policy", choices=["none", "depth", "deadline"],
                   default=None,
                   help="load shedding for batch-class requests: 'depth' "
                        "rejects past --shed-depth queued+running, "
                        "'deadline' rejects when the projected wait blows "
                        "the request deadline (--serve only)")
    p.add_argument("--shed-depth", type=int, default=None,
                   help="queue-depth threshold for --shed-policy depth")
    p.add_argument("--kv-snapshot", default=None, metavar="DIR",
                   help="persist the radix index + KV pages here after "
                        "the run and warm-restore them before it "
                        "(paged layout only)")
    # multi-device serving (launch/mesh.py + launch/router.py)
    p.add_argument("--tp", type=int, default=None,
                   help="tensor-parallel degree of one engine replica: "
                        "params and GQA page pools shard over the mesh's "
                        "'model' axis (needs tp devices per replica)")
    p.add_argument("--replicas", type=int, default=None,
                   help="data-parallel engine replicas behind the "
                        "EngineFleet router (--serve only; each replica is "
                        "a full engine with its own page pool)")
    p.add_argument("--routing", choices=["prefix", "random"], default=None,
                   help="fleet request routing: 'prefix' hashes the first "
                        "page-aligned prompt chunk so shared prefixes land "
                        "on the replica that has them cached (default); "
                        "'random' is the seeded uniform baseline")
    args = p.parse_args(argv)

    if args.preempt_demo and args.serve:
        # the demo drives the batcher synchronously to print its canned
        # counters; the async server owns the loop — the two can't share it
        p.error("--serve and --preempt-demo are mutually exclusive")
    for flag, name in ((args.rate, "--rate"),
                       (args.deadline_ms, "--deadline-ms"),
                       (args.serve_slo, "--serve-slo"),
                       # chaos / supervision / shedding live in the
                       # AsyncServer engine loop; the sync batcher path
                       # has no ticks to retry or streams to time out
                       (args.chaos_seed, "--chaos-seed"),
                       (args.chaos_kill_tick, "--chaos-kill-tick"),
                       (args.request_timeout_s, "--request-timeout-s"),
                       (args.shed_policy, "--shed-policy"),
                       (args.shed_depth, "--shed-depth")):
        if flag is not None and not args.serve:
            p.error(f"{name} requires --serve")
    if args.shed_policy == "depth" and args.shed_depth is None:
        p.error("--shed-policy depth requires --shed-depth")
    if args.shed_depth is not None and args.shed_policy != "depth":
        p.error("--shed-depth requires --shed-policy depth")
    if args.serve:
        args.continuous = True
        if args.kv_layout == "dense":
            # the front door drives step_overlapped, which pipelines the
            # paged engine; the dense slab has no overlapped path
            p.error("--serve requires --kv-layout paged "
                    "(the overlapped engine loop pipelines the paged engine)")
    if args.preempt_demo:
        args.continuous = args.preempt = True
    if args.replicas is not None and not args.serve:
        # replicas are AsyncServer engines behind the fleet router; only
        # the async front door owns more than one engine loop
        p.error("--replicas requires --serve")
    if args.replicas is not None and args.replicas < 1:
        p.error(f"--replicas must be >= 1, got {args.replicas}")
    if args.routing is not None and (args.replicas or 1) <= 1:
        # routing picks between fleet replicas; one engine has no choice
        p.error("--routing requires --replicas > 1")
    if args.tp is not None and not args.continuous:
        # TP shards the serving engine's compiled shapes; the plain
        # generate path never builds them
        p.error("--tp requires --continuous (or --serve)")
    if args.tp is not None and args.tp < 1:
        p.error(f"--tp must be >= 1, got {args.tp}")
    if args.preempt and not args.continuous:
        # preemption is a property of the ContinuousBatcher's page pool;
        # the plain generate path has no pool to oversubscribe
        p.error("--preempt requires --continuous")
    if args.preempt and args.kv_layout == "dense":
        # the dense slab reserves a full (max_len) row range per slot up
        # front — there are no pages to evict, so the flag would be a no-op
        # that silently changes nothing; reject it like --kv-storage packed
        p.error("--preempt requires --kv-layout paged "
                "(the dense slab has no pages to evict)")
    if args.kv_snapshot is not None and not args.continuous:
        # the snapshot persists the KVCacheManager's radix tree + page
        # pool; the plain generate path has neither
        p.error("--kv-snapshot requires --continuous (or --serve)")
    if args.kv_snapshot is not None and args.kv_layout == "dense":
        p.error("--kv-snapshot requires --kv-layout paged "
                "(it persists radix-indexed KV pages)")
    if args.kv_storage in ("packed", "packed4") and not args.continuous:
        # packed pages live in the ContinuousBatcher's paged pool; the plain
        # generate path has no packed store, and silently enabling KV
        # fake-quant there would change tokens while packing nothing
        p.error(f"--kv-storage {args.kv_storage} requires --continuous")
    if args.kv_storage == "packed4" and args.paged_attn != "fused":
        # the jnp fallback would gather + dequantise nibble pages to bf16
        # every tick — packed4 exists to cut decode bandwidth, and only the
        # fused kernel decodes it in VMEM; reject instead of quietly running
        # the slow path
        p.error("--kv-storage packed4 requires --paged-attn fused "
                "(the unfused jnp path would dequantise nibble pages "
                "per tick)")
    if args.paged_attn == "fused":
        if not args.continuous:
            p.error("--paged-attn fused requires --continuous (or --serve)")
        if args.kv_layout == "dense" or args.kv_storage == "fp":
            p.error("--paged-attn fused requires --kv-layout paged with "
                    "--kv-storage packed or packed4 (the kernel decodes "
                    "int8 BBFP pages)")
        # --tp composes: fused + TP page-shards the KV pool over the
        # "model" axis (flash-decoding sequence parallelism) instead of
        # head-sharding it — no kv_heads divisibility requirement, so even
        # kv_heads < tp serves
    cfg = configs.smoke_config(args.arch) if args.smoke else configs.full_config(args.arch)
    kv_quant = args.kv_quant
    if kv_quant is None:
        # packed pages need a storage format; BBFP(6,3) is the serving
        # default (8.16-bit class, near-lossless KV); packed4's codes must
        # fit one nibble, so its default is the widest 4-bit member BBFP(2,1)
        kv_quant = {"packed": "BBFP(6,3)", "packed4": "BBFP(2,1)"}.get(
            args.kv_storage, "none")
    elif kv_quant.lower() == "none" and args.kv_storage in ("packed", "packed4"):
        p.error(f"--kv-storage {args.kv_storage} needs a KV format "
                "(--kv-quant), it is the page storage format")
    qcfg = Q.QuantConfig(linear=args.quant, nonlinear=args.nonlinear,
                         kv_cache=kv_quant)
    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    extras = {}
    if cfg.vis_len:
        extras["vis_embed"] = jax.random.normal(
            key, (args.batch, cfg.vis_len, cfg.d_model)) * 0.1
    if cfg.family == "whisper":
        extras["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder.n_frames, cfg.d_model)) * 0.1

    if args.tp is not None:
        # one serving cell: (dp=1, tp) over the first tp devices; raises
        # with the force-host-device hint when the host has too few
        mesh = bat_mesh = make_serving_mesh(tp=args.tp)
    else:
        mesh, bat_mesh = make_host_mesh(), None
    if args.continuous:
        from repro.runtime.batcher import ContinuousBatcher, Request
        assert cfg.family == "decoder", "continuous mode targets decoders"
        gen = args.gen
        if args.preempt_demo:
            # oversubscribed pool, mixed lengths: every request fits the
            # pool ALONE, the concurrent mix does not — admission fills the
            # pool with prompt pages and the first decode page-boundary
            # crossings force preemptions + recompute-on-readmit
            args.shared_prefix = args.shared_prefix or args.page_size
            gen = max(gen, args.page_size)
            p_lens = [args.page_size + 9 + (7 * i) % 17
                      for i in range(args.batch)]
            if args.n_pages is None:
                args.n_pages = 2 * args.slots   # prompt pages only: tight
        else:
            p_lens = [max(1, args.prompt_len - 4 + (3 * i) % 9)
                      for i in range(args.batch)]
        def make_batcher(runner=None):
            return ContinuousBatcher(cfg, params, qcfg, n_slots=args.slots,
                                     max_len=args.max_len,
                                     kv_layout=args.kv_layout,
                                     kv_storage=args.kv_storage,
                                     page_size=args.page_size,
                                     n_pages=args.n_pages,
                                     prefix_cache=args.prefix_cache,
                                     prefill_chunk=args.prefill_chunk,
                                     prefill_slots=args.prefill_slots,
                                     preempt=args.preempt,
                                     runner=runner, mesh=bat_mesh,
                                     paged_attn=args.paged_attn)

        bat = make_batcher()
        shared = jax.random.randint(jax.random.fold_in(key, 999),
                                    (args.shared_prefix,), 0, cfg.vocab)
        prompt_list = []
        for i, p_len in enumerate(p_lens):   # ragged mix
            prompt = jax.random.randint(jax.random.fold_in(key, i),
                                        (p_len,), 0, cfg.vocab)
            if args.shared_prefix:    # shared-system-prompt workload
                prompt = jnp.concatenate([shared, prompt])
            prompt_list.append(prompt)
        if args.kv_snapshot:
            # warm restart: adopt any prior run's radix/page snapshot so
            # the first round of prompts hits the prefix cache
            n = bat.restore_kv(args.kv_snapshot)
            print(f"kv-snapshot: restored {n} pages from "
                  f"{args.kv_snapshot}" if n else
                  f"kv-snapshot: no snapshot in {args.kv_snapshot} "
                  f"(cold start)")
        if args.serve:
            # fleet replicas share ONE runner: the compiled TP programs and
            # the (possibly sharded) param tree exist once per process
            bats = [bat] + [make_batcher(runner=bat.runner)
                            for _ in range((args.replicas or 1) - 1)]
            mets = _serve_async(args, bats, prompt_list, gen, mesh)
            if args.kv_snapshot:
                n = bat.snapshot_kv(args.kv_snapshot)
                print(f"kv-snapshot: wrote {n} radix nodes to "
                      f"{args.kv_snapshot}")
            return mets
        for i, prompt in enumerate(prompt_list):
            bat.submit(Request(rid=i, prompt=prompt, max_new=gen))
        with PT.activation_sharding(mesh, PT.SERVE_RULES):
            t0 = time.perf_counter()
            finished, ticks = bat.run()
            dt = time.perf_counter() - t0
        n_new = sum(len(r.out_tokens) for r in finished)
        stats = bat.kv_stats()
        print(f"arch={cfg.name} quant={qcfg.linear}/{qcfg.nonlinear} "
              f"layout={stats['kv_layout']} storage={stats['kv_storage']}")
        print(f"served {len(finished)} requests / {n_new} tokens in "
              f"{dt:.2f}s over {ticks} ticks ({bat.decode_calls} decode "
              f"calls, {bat.prefill_traces} prefill traces, "
              f"{bat.chunk_prefill_calls} prefill chunks in "
              f"{bat.prefill_steps} batched steps)")
        if bat.paged:
            print(f"prefix cache: hit rate {bat.prefix_hit_rate:.0%} "
                  f"({bat.prefix_hit_pages} of "
                  f"{bat.prefix_hit_pages + bat.prefix_miss_pages} prompt "
                  f"pages served from resident pages; radix index "
                  f"{stats['radix_pages']} pages)")
        if args.preempt:
            done = sum(len(r.out_tokens) == gen for r in finished)
            print(f"preemption: pool {stats['pages_total']} pages for "
                  f"{len(p_lens)} requests -> {stats['preemptions']} "
                  f"preemptions, {stats['recomputed_tokens']} tokens "
                  f"recomputed on readmit, {done}/{len(p_lens)} requests "
                  f"ran to full budget")
        if args.kv_snapshot:
            n = bat.snapshot_kv(args.kv_snapshot)
            print(f"kv-snapshot: wrote {n} radix nodes to "
                  f"{args.kv_snapshot}")
        print("kv:", {k: v for k, v in stats.items() if k != "kv_layout"})
        return finished
    with PT.activation_sharding(mesh, PT.SERVE_RULES):
        t0 = time.perf_counter()
        tokens = generate(cfg, params, prompts, qcfg, args.gen, extras)
        jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
    n_new = args.batch * args.gen
    print(f"arch={cfg.name} quant={qcfg.linear}/{qcfg.nonlinear}")
    print(f"generated {n_new} tokens in {dt:.2f}s  ({n_new/dt:.1f} tok/s)")
    print("sample:", tokens[0, :16].tolist())
    return tokens


if __name__ == "__main__":
    main()
