"""Per-op cost census over the rolled-up HLO call graph — the 'profiler'
for the dry-run perf loop (§Perf). Buckets (op kind, result shape) by bytes
and flops with while-loop trip multiplication.

Costing rules MIRROR hlo_analysis.analyze (keep in sync): in-place dus /
dynamic-slice / gather / scatter cost only the moved region; fusions whose
result aliases a dominant operand (scan-carried buffers) cost the delta.
"""
from __future__ import annotations

from collections import defaultdict

from repro.launch import hlo_analysis as H


def census(hlo_text: str, total_devices: int = 1):
    comps = H.parse_computations(hlo_text)
    import re
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    entry = m.group(1) if m else list(comps)[-1]

    shapes = {}
    for cname, lines in comps.items():
        d = {}
        for line in lines:
            mi = H._INSTR_RE.match(line)
            if mi:
                d[mi.group(1)] = mi.group(2)
        shapes[cname] = d

    buckets = defaultdict(lambda: {"bytes": 0.0, "flops": 0.0, "count": 0.0})
    stack = []

    def operand_info(cname, line):
        body = line.split("(", 1)[1] if "(" in line else ""
        body = body.split("), ")[0]
        out = []
        for nm in H._OPERAND_RE.findall(body):
            s = shapes[cname].get(nm)
            if s:
                n, b = H._result_numel_bytes(s)
                out.append((b, n))
        return out

    def walk(name, mult, depth=0):
        name = name.lstrip("%")
        if depth > 40 or name in stack:
            return
        stack.append(name)
        for line in comps.get(name, []):
            mi = H._INSTR_RE.match(line)
            if not mi:
                continue
            _nm, result, op = mi.groups()
            numel, rbytes = H._result_numel_bytes(result)
            if op == "while":
                mw = H._WHILE_RE.search(line)
                if mw:
                    trips = H._trip_count(comps.get(mw.group(1).lstrip("%"), []))
                    walk(mw.group(2), mult * trips, depth + 1)
                continue
            if op in ("call",):
                mc = H._TO_APPLY_RE.search(line) or H._CALLS_RE.search(line)
                if mc:
                    walk(mc.group(1), mult, depth + 1)
                continue
            if op == "fusion":
                mc = H._CALLS_RE.search(line)
                key = "fusion"
                if mc:
                    inner = comps.get(mc.group(1).lstrip("%"), [])
                    kinds = sorted({H._INSTR_RE.match(l).group(3)
                                    for l in inner if H._INSTR_RE.match(l)}
                                   - H._ZERO_COST)
                    key = f"fusion[{','.join(kinds[:4])}]"
                    walk(mc.group(1), mult, depth + 1)
                oi = operand_info(name, line)
                ob = sum(b for b, _ in oi)
                aliased = [b for b, n in oi if n == numel and n > 0]
                rest = ob - (max(aliased) if aliased else 0)
                if aliased and rest * 8 <= max(aliased):
                    byt = 2.0 * rest + min(rbytes, 4 * rest)
                else:
                    byt = rbytes + ob
                b = buckets[(key, result[:48])]
                b["bytes"] += mult * byt
                b["count"] += mult
                continue
            if op in H._ZERO_COST:
                continue
            base = op.replace("-start", "")
            if base in H._COLLECTIVES or base in (
                    "all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute"):
                b = buckets[(op, result[:48])]
                b["bytes"] += mult * rbytes * 2.0
                b["count"] += mult
                continue
            b = buckets[(op, result[:48])]
            if op == "dynamic-update-slice":
                oi = operand_info(name, line)
                ub = oi[1][0] if len(oi) > 1 else rbytes
                b["bytes"] += mult * 2.0 * ub
            elif op in ("dynamic-slice", "gather"):
                b["bytes"] += mult * 2.0 * rbytes
            elif op == "scatter":
                oi = [x for x, _ in operand_info(name, line)]
                b["bytes"] += mult * (2.0 * (sum(oi) - max(oi)) if oi else rbytes)
            else:
                b["bytes"] += mult * (rbytes + sum(x for x, _ in operand_info(name, line)))
            b["count"] += mult
            if op == "dot":
                k = H._dot_contract_size(name, line, shapes)
                b["flops"] += mult * 2.0 * numel * k
            elif base in H._ELEMENTWISE:
                b["flops"] += mult * numel
        stack.pop()

    walk(entry, 1.0)
    return buckets


def top(buckets, by="bytes", n=25):
    rows = sorted(buckets.items(), key=lambda kv: -kv[1][by])[:n]
    out = []
    for (op, shape), v in rows:
        out.append(f"{v[by]:.3e}  {op:40s} {shape:48s} x{v['count']:.0f} "
                   f"(flops {v['flops']:.2e})")
    return out
