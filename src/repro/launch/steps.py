"""jit-able step functions (train / prefill / decode) shared by the dry-run,
the trainers and the examples."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import adamw as O
from repro.optim import compression as GC
from repro.quant import linear as Q


def make_train_step(cfg, ocfg: O.AdamWConfig, qcfg: Q.QuantConfig,
                    compress_grads: bool = False, remat: bool = True):
    """state = {"params","opt"[,"err"]}; batch = {"tokens","labels",...}.

    Gradient mean across the sharded batch falls out of autodiff under jit
    (GSPMD inserts the reduce); the optional int8+error-feedback compression
    emulates the compressed cross-pod all-reduce (see optim.compression).
    """

    def train_step(state, batch):
        def lossf(p):
            return M.loss_fn(p, cfg, batch, qcfg, remat=remat)
        (loss, metrics), grads = jax.value_and_grad(lossf, has_aux=True)(state["params"])
        if compress_grads:
            grads, err = GC.compress_gradients(grads, state["err"])
        params, opt, om = O.adamw_update(ocfg, state["params"], grads, state["opt"])
        new_state = {"params": params, "opt": opt}
        if compress_grads:
            new_state["err"] = err
        return new_state, {**metrics, **om}

    return train_step


def make_init_state(cfg, ocfg, key, compress_grads: bool = False):
    params = M.init(cfg, key)
    state = {"params": params, "opt": O.adamw_init(params)}
    if compress_grads:
        state["err"] = GC.compression_init(params)
    return state


def make_prefill_step(cfg, qcfg: Q.QuantConfig, max_len: int | None = None):
    def prefill_step(params, batch):
        extras = {k: v for k, v in batch.items() if k in ("vis_embed", "frames")}
        return M.prefill(params, cfg, batch["tokens"], qcfg, max_len=max_len, **extras)
    return prefill_step


def make_decode_step(cfg, qcfg: Q.QuantConfig):
    def decode_step(params, cache, batch):
        return M.decode_step(params, cfg, cache, batch["tokens"], qcfg)
    return decode_step
