"""Training launcher.

Runs REAL training on whatever devices exist (CPU in this container, with
the host mesh) and supports every --arch at --smoke scale; the production
mesh path is exercised via dryrun.py. Fault tolerance: async checkpoints,
failure injection, automatic restore, straggler monitor (repro.runtime).

  PYTHONPATH=src python -m repro.launch.train --arch llama7b --smoke \
      --steps 200 --batch 8 --seq 128 --quant "BBFP(4,2)"
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import SyntheticLMDataset
from repro.launch import sharding as S
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import partitioning as PT
from repro.optim import adamw as O
from repro.quant import linear as Q
from repro.runtime import FailureInjector, StragglerMonitor, resilient_train_loop


def build(args):
    cfg = configs.smoke_config(args.arch) if args.smoke else configs.full_config(args.arch)
    if args.tiny:
        cfg = configs.get("llama7b").tiny_lm_config(vocab=args.vocab)
    qcfg = Q.QuantConfig(linear=args.quant, nonlinear=args.nonlinear)
    ocfg = O.AdamWConfig(lr=args.lr, total_steps=args.steps,
                         warmup_steps=max(args.steps // 20, 5))
    return cfg, qcfg, ocfg


def make_batch_fn(cfg, args):
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed)

    def batch_fn(step):
        b = ds.batch(step, args.batch)
        out = {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])}
        if cfg.vis_len:
            key = jax.random.PRNGKey(step)
            out["vis_embed"] = jax.random.normal(
                key, (args.batch, cfg.vis_len, cfg.d_model), jnp.float32) * 0.1
        if cfg.family == "whisper":
            key = jax.random.PRNGKey(step + 1)
            out["frames"] = jax.random.normal(
                key, (args.batch, cfg.encoder.n_frames, cfg.d_model), jnp.float32) * 0.1
        return out

    return batch_fn


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama7b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--no-smoke", dest="smoke", action="store_false")
    p.add_argument("--tiny", action="store_true",
                   help="use the ~100M-class tiny-LM config")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--vocab", type=int, default=512)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--quant", default="none")
    p.add_argument("--nonlinear", default="none")
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=25)
    p.add_argument("--fail-at", type=int, nargs="*", default=[],
                   help="inject failures at these steps (fault-tolerance demo)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    cfg, qcfg, ocfg = build(args)
    mesh = make_host_mesh()
    print(f"arch={cfg.name} params on mesh {dict(mesh.shape)} quant={qcfg.linear}"
          f"/{qcfg.nonlinear} steps={args.steps}")

    state = ST.make_init_state(cfg, ocfg, jax.random.PRNGKey(args.seed),
                               compress_grads=args.compress_grads)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"params: {n_params:,}")
    step_fn = jax.jit(ST.make_train_step(cfg, ocfg, qcfg,
                                         compress_grads=args.compress_grads,
                                         remat=False))
    batch_fn = make_batch_fn(cfg, args)

    with PT.activation_sharding(mesh, PT.TRAIN_RULES):
        state, hist = resilient_train_loop(
            init_state=state, step_fn=step_fn, batch_fn=batch_fn,
            n_steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            injector=FailureInjector(tuple(args.fail_at)),
            monitor=StragglerMonitor(), log_every=args.log_every)

    print(f"final loss {hist['loss'][-1]:.4f}  restarts={hist['restarts']} "
          f"stragglers={len(hist['stragglers'])}")
    return state, hist


if __name__ == "__main__":
    main()
