"""Render results/dryrun.json into the EXPERIMENTS.md §Roofline table:
three terms per (arch x shape), dominant bottleneck, MODEL_FLOPS ratio,
and a one-line 'what would move the dominant term' note.
"""
from __future__ import annotations

import argparse
import json
import os

from repro import configs
from repro.launch import specs as SP

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun.json")

CHIP_PEAK = 197e12
N_CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops_per_chip(arch: str, shape: str, n_chips: int) -> float:
    """MODEL_FLOPS: 6·N·D train / 2·N_active·D prefill / 2·N_active decode,
    divided across chips."""
    cfg = configs.full_config(arch)
    n = cfg.param_count()
    # active params for MoE (routed experts count only top_k/E of expert mass)
    n_active = n
    if cfg.moe:
        m = cfg.moe
        expert_params = (cfg.n_layers - m.first_dense) * m.n_experts * 3 * cfg.d_model * m.d_expert
        n_active = n - expert_params * (1 - m.top_k / m.n_experts)
    sh = SP.SHAPES[shape]
    tokens = sh["batch"] * sh["seq"]
    if sh["kind"] == "train":
        total = 6.0 * n_active * tokens      # 6·N_active·D for MoE, 6·N·D dense
    elif sh["kind"] == "prefill":
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * sh["batch"]
    return total / n_chips


def hint(dom: str, shape: str, arch: str) -> str:
    if dom == "memory":
        if "decode" in shape or "long" in shape:
            return "KV/cache traffic dominates: shrink or shard the cache (ring buffers, grouped local/global caches), quantise KV to BBFP"
        return "activation + quant-op traffic: chunked attention (never materialise S^2 probs), bf16 quant ops, fuse fake-quant into the matmul"
    if dom == "collective":
        return "reshard: reduce weight all-gather volume (bigger FSDP grain), overlap collectives with compute, compress cross-pod grads"
    return "compute-bound: int8 MXU path for BBFP<=4 mantissas halves cycles vs bf16"


def render(results_path: str = RESULTS, quant: str = "paper",
           mesh: str = "16x16") -> str:
    with open(results_path) as f:
        res = json.load(f)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPs/HLO | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    meshname = "single" if mesh == "16x16" else "multi"
    for arch in [a.replace("_", "-") for a in configs.ARCHS if a != "llama7b"]:
        for shape in SP.SHAPES:
            key = f"{arch}|{shape}|{meshname}|{quant}"
            r = res.get(key)
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | skipped | — | {r['reason'][:40]} |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | — | — | — | ERROR | — | {r.get('error','')[:40]} |")
                continue
            t = r["roofline"]
            terms = {"compute": t["compute_s"], "memory": t["memory_s"],
                     "collective": t["collective_s"]}
            dom = max(terms, key=terms.get)
            mf = model_flops_per_chip(arch, shape, r["n_chips"])
            ratio = mf / max(t["flops"], 1.0)
            lines.append(
                f"| {arch} | {shape} | {terms['compute']:.2e} | "
                f"{terms['memory']:.2e} | {terms['collective']:.2e} | {dom} | "
                f"{ratio:.2f} | {hint(dom, shape, arch)[:80]} |")
    return "\n".join(lines)


def summary(results_path: str = RESULTS, quant: str = "paper"):
    """Pick hillclimb candidates: worst roofline fraction, most
    collective-bound, most paper-representative."""
    with open(results_path) as f:
        res = json.load(f)
    rows = []
    for key, r in res.items():
        if r.get("status") != "ok" or f"|{quant}" not in key:
            continue
        arch, shape, meshname, _ = key.split("|")
        if meshname != "single":
            continue
        t = r["roofline"]
        mf = model_flops_per_chip(arch, shape, r["n_chips"])
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = (mf / CHIP_PEAK) / max(bound, 1e-12)  # useful-compute fraction
        rows.append({"arch": arch, "shape": shape, "frac": frac,
                     "coll_ratio": t["collective_s"] / max(bound, 1e-12),
                     "terms": (t["compute_s"], t["memory_s"], t["collective_s"])})
    rows.sort(key=lambda r: r["frac"])
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16"])
    p.add_argument("--quant", default="paper")
    args = p.parse_args()
    print(render(mesh=args.mesh, quant=args.quant))
    print("\nWorst useful-compute fractions (hillclimb candidates):")
    for r in summary(quant=args.quant)[:8]:
        print(f"  {r['arch']:24s} {r['shape']:12s} frac={r['frac']:.4f} "
              f"coll_share={r['coll_ratio']:.2f}")
