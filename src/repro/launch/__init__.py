"""Launch layer: meshes, sharding rules, input-shape cells, dry-run,
trainers and the serving driver. dryrun.py is the multi-pod proof:
lower+compile every (arch x shape) on the 16x16 and 2x16x16 meshes."""
