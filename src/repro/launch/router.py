"""Prefix-affinity request routing across data-parallel engine replicas.

``EngineFleet`` is the multi-replica front door: it owns N ``AsyncServer``
replicas (each a full engine — Scheduler / KVCacheManager / ModelRunner —
typically sharing ONE mesh-aware ModelRunner so the compiled TP programs
and sharded params exist once per process) and routes every request at
submit time. Replicas are DATA parallel: they share no KV state, so the
radix prefix tree that makes shared prompts cheap (PR 4/5) is per-replica
— scattering a prefix-sharing workload uniformly would split every prefix
group across replicas and pay the prefill once per replica instead of
once per fleet.

ROUTING POLICY ("prefix"): hash the request's FIRST PAGE-ALIGNED PROMPT
CHUNK — the same 32-token page granularity the radix tree indexes, so two
prompts that could ever share a cached page necessarily share a route key
— and send the request to ``hash % n_replicas``. Requests with a common
prefix therefore concentrate on the replica that already holds it, and
the per-replica radix hit rate approaches the single-replica rate instead
of degrading with fleet size. The hash is sha256 over the raw int32
little-endian bytes (python's builtin ``hash`` is salted per process —
useless for a deterministic, restart-stable assignment).

SPILL: affinity must not defeat load balancing. When the affinity
target's load (queued + staged + running) is at or past
``spill_threshold``, the request spills to the least-loaded replica
(first index wins ties) and the spill is counted — cache-cold but
latency-warm.

The "random" policy (seeded, deterministic) is the control: the bench
gate requires prefix routing to beat it on radix hit rate for the
deterministic shared-prefix workload.

HEALTH + FAILOVER. Each replica reports ``AsyncServer.health`` (ok /
slow / dead — derived from its tick monitor and fatal-failure state).
Routing excludes dead replicas: an affinity target that died reroutes to
the least-loaded healthy replica (counted in ``reroutes``). In-flight
work survives a replica death transparently: ``submit`` returns a
``FleetStream`` which, when its underlying stream fails because its
replica died, RESUBMITS the same prompt on a surviving replica and
skip-consumes the tokens already delivered — greedy decode is
deterministic, so the replay is token-identical and the consumer sees
one uninterrupted stream. Retries are bounded by the replica count; the
per-request outcome ledger keeps the dead replica's failed record, so
the failover is visible in metrics, not papered over.
"""
from __future__ import annotations

import hashlib

import numpy as np

from repro.runtime import paged_kv as PK

ROUTING_POLICIES = ("prefix", "random")


def prefix_route_key(prompt, page: int = PK.PAGE_SIZE) -> bytes:
    """The routing key: raw bytes of the first page-aligned prompt chunk
    (the whole prompt when shorter than a page). Page granularity matches
    the radix tree's chunk size, so prompts that can share ANY cached page
    share a key."""
    toks = np.asarray(prompt, np.int32).reshape(-1)[:page]
    return toks.tobytes()


def prefix_replica(prompt, n_replicas: int, page: int = PK.PAGE_SIZE) -> int:
    """Deterministic replica index for a prompt (sha256, not the per-process
    salted builtin hash): stable across processes and restarts."""
    digest = hashlib.sha256(prefix_route_key(prompt, page)).digest()
    return int.from_bytes(digest[:8], "big") % n_replicas


class FleetRouter:
    """Pure-host routing policy: prompt + per-replica loads -> replica.
    Separated from the fleet so the policy is unit-testable without
    servers (and swappable: ``pick`` is the whole surface)."""

    def __init__(self, n_replicas: int, *, policy: str = "prefix",
                 page: int = PK.PAGE_SIZE,
                 spill_threshold: int | None = None, seed: int = 0):
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"one of {ROUTING_POLICIES}")
        assert n_replicas >= 1
        self.n = n_replicas
        self.policy = policy
        self.page = page
        self.spill_threshold = spill_threshold
        self._rng = np.random.default_rng(seed)
        self.picks = [0] * n_replicas
        self.spills = 0
        self.reroutes = 0                # picks redirected off dead replicas

    def pick(self, prompt, loads, healthy=None) -> int:
        """Route one prompt. `healthy` (optional bool per replica) masks
        replicas out of consideration — a dead affinity target reroutes to
        the least-loaded healthy replica (cache-cold but alive)."""
        assert len(loads) == self.n, (len(loads), self.n)
        healthy = list(healthy) if healthy is not None else [True] * self.n
        if not any(healthy):
            raise RuntimeError("no healthy replica to route to")

        def least_loaded():
            return min((i for i in range(self.n) if healthy[i]),
                       key=lambda i: (loads[i], i))  # first index wins ties

        if self.policy == "random":
            r = int(self._rng.integers(self.n))
            if not healthy[r]:
                r = least_loaded()
                self.reroutes += 1
        else:
            r = prefix_replica(prompt, self.n, self.page)
            if not healthy[r]:
                r = least_loaded()
                self.reroutes += 1
            elif self.spill_threshold is not None and \
                    loads[r] >= self.spill_threshold:
                r = least_loaded()
                self.spills += 1
        self.picks[r] += 1
        return r


class FleetStream:
    """Failover-transparent token stream. Wraps one replica's
    ``TokenStream``; when the stream fails BECAUSE ITS REPLICA DIED, the
    request is resubmitted on a surviving replica and the tokens already
    delivered are skip-consumed from the replay — greedy decode is
    deterministic (and packed pages are bit-exact), so the retried stream
    emits the identical token sequence and the consumer never notices.
    Per-request failures (poison, timeout, shed) on a LIVE replica are
    not retried: they re-raise as the request's terminal outcome."""

    def __init__(self, fleet, prompt, max_new: int, kw: dict,
                 replica: int, stream):
        self._fleet = fleet
        self._prompt, self._max_new, self._kw = prompt, max_new, kw
        self._replica, self._stream = replica, stream
        self._emitted = 0                # tokens delivered to the consumer
        self._skip = 0                   # replay tokens to swallow
        self._retries = 0

    @property
    def request(self):
        return self._stream.request

    @property
    def replica(self) -> int:
        return self._replica

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        while True:
            try:
                tok = await self._stream.__anext__()
            except StopAsyncIteration:
                raise
            except Exception:
                srv = self._fleet.servers[self._replica]
                if getattr(srv, "_dead", None) is None or \
                        self._retries >= len(self._fleet.servers) - 1:
                    raise                # per-request failure, or no survivor
                self._failover()
                continue
            if self._skip:               # replay of already-delivered tokens
                self._skip -= 1
                continue
            self._emitted += 1
            return tok

    def _failover(self):
        self._retries += 1
        self._fleet.failovers += 1
        r, stream = self._fleet._route_submit(
            self._prompt, self._max_new, self._kw)
        self._replica, self._stream = r, stream
        self._skip = self._emitted


class EngineFleet:
    """N-replica front door with the single-server surface ``closed_loop``
    drives: ``submit`` routes to a healthy replica's ``AsyncServer.submit``
    and returns a failover-wrapping ``FleetStream``; ``metrics``
    concatenates per-request records across replicas."""

    def __init__(self, servers, *, routing: str = "prefix",
                 page: int = PK.PAGE_SIZE,
                 spill_threshold: int | None = None, seed: int = 0):
        assert servers, "a fleet needs at least one replica"
        self.servers = list(servers)
        self.router = FleetRouter(len(self.servers), policy=routing,
                                  page=page, spill_threshold=spill_threshold,
                                  seed=seed)
        self.assignments: list[int] = []   # replica per submit, submit order
        self.failovers = 0                 # in-flight streams retried

    async def start(self):
        for srv in self.servers:
            await srv.start()

    async def shutdown(self, drain: bool = True):
        for srv in self.servers:
            await srv.shutdown(drain=drain)

    def _loads(self) -> list[int]:
        """Per-replica outstanding work: staged (accepted, not yet inside
        the engine) + queued + running."""
        return [len(srv._staged) + srv.bat.sched.outstanding()
                for srv in self.servers]

    def health(self) -> list[str]:
        """Per-replica health (ok / slow / dead), routing's input."""
        return [getattr(srv, "health", "ok") for srv in self.servers]

    def _route_submit(self, prompt, max_new: int, kw: dict):
        """Pick a NON-DEAD replica (slow still routes — it makes progress)
        and submit. Shared by first submission and failover retry."""
        healthy = [h != "dead" for h in self.health()]
        r = self.router.pick(prompt, self._loads(), healthy)
        return r, self.servers[r].submit(prompt, max_new, **kw)

    def submit(self, prompt, max_new: int, **kw):
        r, stream = self._route_submit(prompt, max_new, kw)
        self.assignments.append(r)
        return FleetStream(self, prompt, max_new, kw, r, stream)

    def metrics(self):
        out = []
        for srv in self.servers:
            out.extend(srv.metrics())
        return out

    def counters(self) -> dict:
        """Aggregate engine counters plus the fleet-level affinity proof:
        ``fleet_affinity_hit_rate`` is the pooled radix hit rate over every
        replica's admitted prompt pages — the number prefix routing must
        keep at the single-replica level and random routing degrades."""
        per = [srv.counters() for srv in self.servers]
        hit = sum(srv.bat.prefix_hit_pages for srv in self.servers)
        miss = sum(srv.bat.prefix_miss_pages for srv in self.servers)
        agg = {k: sum(c[k] for c in per) for k in per[0]
               if not isinstance(per[0][k], str)}
        agg.update(replicas=len(self.servers),
                   routing=self.router.policy,
                   picks=list(self.router.picks),
                   spills=self.router.spills,
                   reroutes=self.router.reroutes,
                   failovers=self.failovers,
                   health=self.health(),
                   fleet_prefix_hit_pages=hit,
                   fleet_prefix_miss_pages=miss,
                   fleet_affinity_hit_rate=hit / (hit + miss)
                   if hit + miss else 0.0)
        return agg
