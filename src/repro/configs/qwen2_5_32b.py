"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="decoder",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=27648, vocab=152064, act="silu", qkv_bias=True, rope_theta=1e6,
)


def smoke_config():
    return ArchConfig(
        name="qwen2.5-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, act="silu", qkv_bias=True,
    )
