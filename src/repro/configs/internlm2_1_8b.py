"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544. [arXiv:2403.17297; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b", family="decoder",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92544, act="silu", rope_theta=1e6,
)


def smoke_config():
    return ArchConfig(
        name="internlm2-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, act="silu",
    )
