"""whisper-tiny [audio]: enc-dec, 4+4L d_model=384 6H d_ff=1536
vocab=51865, conv frontend STUBBED (precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.models.common import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="whisper",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab=51865, act="gelu", rope_theta=0.0,
    encoder=EncoderConfig(n_layers=4, n_frames=1500, max_dec_pos=32768),
)


def smoke_config():
    return ArchConfig(
        name="whisper-smoke", family="whisper",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, act="gelu", rope_theta=0.0,
        encoder=EncoderConfig(n_layers=2, n_frames=16, max_dec_pos=128),
    )
