"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="decoder",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab=151936, act="silu", qk_norm=True, rope_theta=1e6,
)


def smoke_config():
    return ArchConfig(
        name="qwen3-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, act="silu", qk_norm=True,
    )
