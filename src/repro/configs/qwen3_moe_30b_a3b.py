"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) moe d_ff=768,
vocab=151936, 128 experts top-8, qk_norm. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.common import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="decoder",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936, act="silu", qk_norm=True, rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768),
)


def smoke_config():
    return ArchConfig(
        name="qwen3-moe-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=512, act="silu", qk_norm=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=64),
    )
