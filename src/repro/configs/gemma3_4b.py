"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5:1 local:global (window 1024), GELU, tied embeddings,
sandwich norms, 128k context. [hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="decoder",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144, act="gelu", rope_theta=1e6,
    tie_embeddings=True, embed_scale=True, post_norm=True, qk_norm=True,
    sliding_window=1024, global_every=6,   # layers 5, 11, ... are global
)


def smoke_config():
    return ArchConfig(
        name="gemma3-smoke", family="decoder",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, act="gelu",
        tie_embeddings=True, embed_scale=True, post_norm=True, qk_norm=True,
        sliding_window=8, global_every=3,
    )
