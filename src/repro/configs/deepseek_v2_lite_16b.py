"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H, MLA kv_lora=512,
moe d_ff=1408, 64 routed top-6 + 2 shared experts, first layer dense,
vocab=102400. [arXiv:2405.04434; hf]"""
from repro.models.common import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="decoder",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102400, act="silu",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  d_shared=1408, first_dense=1, d_ff_dense=10944),
)


def smoke_config():
    return ArchConfig(
        name="deepseek-v2-smoke", family="decoder",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=64, vocab=512, act="silu",
        mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=1,
                      d_shared=64, first_dense=1, d_ff_dense=128),
    )
