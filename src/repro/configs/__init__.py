"""Architecture registry: one module per assigned arch (+ the paper's own
llama7b family). Each module exposes CONFIG (full, dry-run only) and
smoke_config() (reduced, runs on CPU)."""
from __future__ import annotations

import importlib

ARCHS = [
    "internvl2_76b",
    "qwen3_moe_30b_a3b",
    "deepseek_v2_lite_16b",
    "gemma3_4b",
    "qwen2_5_32b",
    "qwen3_32b",
    "internlm2_1_8b",
    "mamba2_2_7b",
    "whisper_tiny",
    "recurrentgemma_2b",
    "llama7b",   # the paper's own evaluation family
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get(name: str):
    """Return the config module for an arch id ('qwen2.5-32b', 'qwen3_32b'...)."""
    mod_name = name.replace(".", "_").replace("-", "_")
    return importlib.import_module(f"repro.configs.{mod_name}")


def full_config(name: str):
    return get(name).CONFIG


def smoke_config(name: str):
    return get(name).smoke_config()
