"""llama7b: the paper's own evaluation family (Table II/IV) — 32L
d_model=4096 32H MHA d_ff=11008 vocab=32000. Used by the benchmarks and
the end-to-end examples (at reduced size)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama7b", family="decoder",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab=32000, act="silu", rope_theta=1e4,
)


def smoke_config():
    return ArchConfig(
        name="llama-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, act="silu",
    )


def tiny_lm_config(vocab: int = 512):
    """~100M-class config for the end-to-end training example (CPU-feasible
    at reduced width) and the Table II PPL benchmark."""
    return ArchConfig(
        name="llama-tiny", family="decoder",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
        d_ff=1024, vocab=vocab, act="silu",
    )
