"""internvl2-76b [vlm]: InternViT frontend (STUB: precomputed patch
embeddings) + InternLM2-style 76B GQA decoder backbone.
[arXiv:2404.16821; unverified]  80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256."""
from repro.models.common import ArchConfig

VIS_LEN = 256   # stub patch embeddings per image

CONFIG = ArchConfig(
    name="internvl2-76b", family="decoder",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256, act="silu", rope_theta=1e6,
    vis_len=VIS_LEN,
)


def smoke_config():
    return ArchConfig(
        name="internvl2-smoke", family="decoder",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, act="silu", vis_len=8,
    )
