"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1, hd=256)
d_ff=7680, RG-LRU + local attention 1:2 (pattern rec,rec,attn),
vocab=256000. [arXiv:2402.19427; hf]"""
from repro.models.common import ArchConfig, GriffinConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="griffin",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000, act="gelu", tie_embeddings=True,
    embed_scale=True,
    griffin=GriffinConfig(lru_width=2560, conv_width=4, window=2048,
                          pattern=("rec", "rec", "attn")),
)


def smoke_config():
    return ArchConfig(
        name="recurrentgemma-smoke", family="griffin",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512, act="gelu", tie_embeddings=True,
        embed_scale=True,
        griffin=GriffinConfig(lru_width=64, conv_width=4, window=8,
                              pattern=("rec", "rec", "attn")),
    )
