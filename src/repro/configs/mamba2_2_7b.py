"""mamba2-2.7b [ssm]: 64L d_model=2560 attn-free, SSD state=128,
head_dim=64 (80 heads at expand=2), vocab=50280. [arXiv:2405.21060;
unverified]"""
from repro.models.common import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="mamba2",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
)


def smoke_config():
    return ArchConfig(
        name="mamba2-smoke", family="mamba2",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
        d_ff=0, vocab=512,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4, chunk=16),
    )
