"""Perf-iteration feature flags (§Perf methodology).

Each beyond-baseline optimisation can be disabled to re-measure the
paper-faithful baseline under the same cost model:

    REPRO_DISABLE_OPT=causal_skip,seqkv_cache python -m repro.launch.dryrun ...

Flags:
  causal_skip  — static KV-chunk skipping in chunked attention (§Perf C/H1)
                 and above-diagonal tile skipping in the fused flash kernel
                 (kernels/flash_lut_attention.py, §Perf C1)
  seqkv_cache  — sequence-parallel KV cache sharding when KV heads don't
                 divide the model axis (§Perf A/H1)
"""
from __future__ import annotations

import os

_disabled = set(
    f.strip() for f in os.environ.get("REPRO_DISABLE_OPT", "").split(",") if f.strip())


def enabled(flag: str) -> bool:
    return flag not in _disabled
