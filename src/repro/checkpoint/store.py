"""Mesh-agnostic checkpointing with atomic commits and async save.

Format: one .npz of host (fully-replicated) arrays keyed by flattened tree
paths + a metadata json (step, keys). Saves go to a temp dir and are
renamed into place, so a crash mid-save never corrupts the latest
checkpoint; restore takes a template pytree (from jax.eval_shape) and puts
leaves back with whatever sharding the *current* mesh dictates — this is
the elastic-restart path (a checkpoint written on a 16x16 mesh restores
onto 2x16x16, 4 devices, or 1 device unchanged; tested).

Production note: at 76B params a real deployment writes per-host shards via
tensorstore/orbax rather than gathering to host 0; the atomic-rename + step
registry + template-driven restore logic here is the part that carries over.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":       # npz has no bf16: upcast losslessly
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    """Atomic synchronous save. Returns the committed path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(flat)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint_arrays(ckpt_dir: str, step: int | None = None):
    """Template-FREE restore: -> (step, {flat_key: np.ndarray}) of the
    committed checkpoint, or (None, None) when the directory holds none.

    ``restore_checkpoint`` needs the target pytree's structure up front;
    snapshot consumers whose shape is data-dependent (the serving KV
    snapshot: the number of radix nodes is only known from the snapshot
    itself) read the flat key->array dict and rebuild their structure
    from it. Keys are the same "/"-joined tree paths ``save_checkpoint``
    writes."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    return step, {k: data[k] for k in data.files}


def restore_checkpoint(ckpt_dir: str, template, step: int | None = None,
                       shardings=None):
    """Restore into the structure of `template` (values ignored). `shardings`
    (optional pytree of NamedSharding) re-lays the arrays onto the current
    mesh — the elastic-restart path."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves_p:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = np.asarray(jax.numpy.asarray(arr, dtype=leaf.dtype))
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return step, tree


class AsyncCheckpointer:
    """Background-thread checkpointing so the train loop never blocks on IO.
    wait() joins the in-flight save (called before process exit and before
    restoring in failure tests)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._save_and_gc, args=(step, host_tree), daemon=True)
        self._thread.start()

    def _save_and_gc(self, step, host_tree):
        save_checkpoint(self.ckpt_dir, step, host_tree)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
