from repro.checkpoint.store import (  # noqa: F401
    save_checkpoint, restore_checkpoint, load_checkpoint_arrays,
    latest_step, AsyncCheckpointer,
)
