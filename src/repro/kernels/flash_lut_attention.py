"""Pallas TPU kernel: flash attention with the BBFP segmented-LUT softmax
FUSED into the tile loop (the paper's Fig. 6 unit living inside VMEM).

Why this kernel exists (EXPERIMENTS.md §Perf): the dominant residual memory
term of the BBFP serving cells is the LUT-exp quantisation chain on score
tiles — ~20 elementwise ops that the CPU lowering materialises in HBM. On
TPU they belong INSIDE the attention kernel: scores never leave VMEM, the
64 KiB exp table is VMEM-resident, and HBM sees only q/k/v/out. This kernel
is that fusion, validated (interpret mode) against the pure-jnp chunked
online-softmax reference to fp32 tolerance.

Grid: (batch*kv_heads*groups, Sq/TQ, Skv/TK), K innermost; m/l/acc carried
in VMEM scratch across the K dimension (same pattern as bbfp_matmul).
Causal K tiles fully above the diagonal are SKIPPED via ``pl.when`` on the
tile index (§Perf C1, mirroring the jnp path's static chunk skip): the
dot/LUT-exp/accumulate body never executes for a tile whose first K
position is past the q tile's last row — ~2x fewer tile FLOPs for square
causal attention (``causal_live_tiles`` is the exact count; the
``causal_skip`` perf flag re-enables compute-all-then-mask for A/B runs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bbfp as B
from repro.core import nonlinear as NL

NEG = -1e30


def _lut_exp_tile(s, table, *, m, o, e_min, a_bits):
    """exp(s) for s<=0 via the segmented LUT; blocks of 32 along the last
    dim (the KV axis) — identical semantics to quant.qexp_for_online_softmax."""
    r, c = s.shape
    nb = c // B.DEFAULT_BLOCK
    xb = s.reshape(r, nb, B.DEFAULT_BLOCK)
    bits = jax.lax.bitcast_convert_type(xb.astype(jnp.float32), jnp.int32)
    e = jnp.where(xb == 0.0, B._EXP_MIN, ((bits >> 23) & 0xFF) - 127)
    e = jnp.clip(e, B._EXP_MIN, B._EXP_MAX)
    e_s = jnp.clip(jnp.max(e, axis=-1) - (m - o), B._EXP_MIN, B._EXP_MAX)
    flag = (e > e_s[..., None]).astype(jnp.int32)
    step = jnp.exp2((e_s[..., None] - m + 1 + flag * (m - o)).astype(jnp.float32))
    q = jnp.clip(jnp.round(jnp.abs(xb) / step), 0, 2**m - 1).astype(jnp.int32)
    addr = q >> (m - a_bits)
    sign_idx = (xb < 0).astype(jnp.int32)
    n_exp, n_addr = table.shape[2], table.shape[3]
    e_idx = jnp.clip(e_s[..., None] - e_min, 0, n_exp - 1)
    comp = ((sign_idx * 2 + flag) * n_exp + e_idx) * n_addr + addr
    y = jnp.take(table.reshape(-1), comp.reshape(r, c), axis=0)
    return y


def causal_live_tiles(sq: int, skv: int, tq: int, tk: int) -> int:
    """Number of (q tile, k tile) pairs the causal kernel actually computes:
    k tile ki is live for q tile qi iff its first K position ki*tk is <= the
    q tile's last row qi*tq + tq - 1. The tile-FLOP cost of one (bh) slice
    is proportional to this count — for sq == skv it approaches half of
    (sq/tq)*(skv/tk), the §Perf C1 win the skip delivers."""
    n_k = skv // tk
    return sum(min(n_k, (qi * tq + tq - 1) // tk + 1)
               for qi in range(sq // tq))


def _flash_kernel(q_ref, k_ref, v_ref, tab_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale, causal, n_k, tq, tk, m_bits, o_bits, e_min, a_bits,
                  exp_lo, skip_masked_tiles):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _tile():
        q = q_ref[0].astype(jnp.float32)                 # (TQ, hd)
        k = k_ref[0].astype(jnp.float32)                 # (TK, hd)
        v = v_ref[0].astype(jnp.float32)                 # (TK, hd_v)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            kpos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            s = jnp.where(kpos <= qpos, s, NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        shifted = jnp.maximum(s - m_new[:, None], exp_lo)   # bounded unit domain
        p = _lut_exp_tile(shifted, tab_ref[...], m=m_bits, o=o_bits,
                          e_min=e_min, a_bits=a_bits)
        if causal:
            p = jnp.where(kpos <= qpos, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if skip_masked_tiles:
        # §Perf C1: a K tile whose first position is past the q tile's last
        # row is fully masked — scratch state is bitwise-unchanged whether
        # we compute-and-mask it or never touch it, so skip it entirely.
        # (causal_live_tiles counts exactly the tiles that run.)
        pl.when(ki * tk <= qi * tq + tq - 1)(_tile)
    else:
        _tile()

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0, ...] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fmt_name", "causal", "tq", "tk",
                                             "interpret"))
def flash_lut_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        fmt_name: str = "BBFP(10,5)", causal: bool = True,
                        tq: int = 128, tk: int = 128,
                        interpret: bool | None = None) -> jax.Array:
    """out[bh, Sq, hd_v] = softmax_LUT(q k^T / sqrt(hd)) v, fused.

    q: (BH, Sq, hd); k: (BH, Skv, hd); v: (BH, Skv, hd_v).
    Sq % tq == 0, Skv % tk == 0, tk % 32 == 0 (LUT block).
    """
    fmt = B.parse_format(fmt_name)
    spec = NL.get_lut("exp", fmt)
    bh, sq, hd = q.shape
    skv = k.shape[1]
    hd_v = v.shape[2]
    assert sq % tq == 0 and skv % tk == 0 and tk % B.DEFAULT_BLOCK == 0
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n_k = skv // tk
    from repro.perf_flags import enabled
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (hd ** 0.5), causal=causal, n_k=n_k,
        tq=tq, tk=tk, m_bits=fmt.mantissa, o_bits=fmt.overlap,
        e_min=spec.e_min, a_bits=NL.ADDRESS_BITS, exp_lo=NL.EXP_LUT_RANGE,
        skip_masked_tiles=causal and enabled("causal_skip"))
    grid = (bh, sq // tq, n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk, hd_v), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec(spec.table.shape, lambda b, i, j: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, hd_v), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),       # running max
            pltpu.VMEM((tq,), jnp.float32),       # running sum
            pltpu.VMEM((tq, hd_v), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v, spec.table)
