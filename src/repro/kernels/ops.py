"""jit'd public wrappers around the Pallas kernels.

These handle arbitrary leading dims + padding, pick interpret mode on CPU,
and fall back to the jnp reference when shapes are too small to tile (the
reference *is* the same arithmetic, so this is purely a dispatch decision).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bbfp as B
from repro.kernels import ref as _ref
from repro.kernels.bbfp_matmul import bbfp_matmul as _matmul_kernel_call
from repro.kernels.bbfp_matmul import bbfp_matmul_packed as _matmul_packed_call
from repro.kernels.lut_nonlinear import lut_apply_kernel

# dispatch floor: at least one natural fp32 (8, 128) output tile's worth of
# work, else the jnp reference wins. Row-thin operands (decode GEMMs: rows =
# batch, N = model dim) still clear this and run the kernel with tm=8 —
# the old `rows * n_dim < 128 * 128` floor sent every batch-sized serving
# GEMM to the reference.
_MIN_KERNEL_ELEMS = 8 * 128


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x


def _row_tile(rows: int) -> int:
    """Output-row tile: full 128 MXU rows when the operand has them, the
    minimal fp32 sublane tile (8) for row-thin decode GEMMs."""
    return 128 if rows >= 128 else 8


def bbfp_matmul(a: jax.Array, b: jax.Array, fmt_name: str = "BBFP(4,2)",
                use_kernel: bool = True) -> jax.Array:
    """C[..., M, N] = Q(a)[..., M, K] @ Q(b)[K, N] in BBFP arithmetic.

    K-block boundaries (32) align between the kernel's 128-wide K tiles and
    the reference's whole-K blocking, so kernel == ref exactly.
    """
    *lead, m_dim, k_dim = a.shape
    n_dim = b.shape[1]
    a2 = a.reshape(-1, k_dim)
    rows = a2.shape[0]
    if not use_kernel or rows * n_dim < _MIN_KERNEL_ELEMS:
        out = _ref.bbfp_matmul_ref(a2, b, fmt_name)
        return out.reshape(*lead, m_dim, n_dim)
    tm = _row_tile(rows)
    a2 = _pad_axis(_pad_axis(a2, tm, 0), 128, 1)
    b2 = _pad_axis(_pad_axis(b, 128, 0), 128, 1)
    out = _matmul_kernel_call(a2, b2, fmt_name, tm=tm)[:rows, :n_dim]
    return out.reshape(*lead, m_dim, n_dim)


def bbfp_matmul_packed(a: jax.Array, packed: dict,
                       fmt_name: str = "BBFP(4,2)",
                       use_kernel: bool = True) -> jax.Array:
    """C[..., M, N] = Q(a)[..., M, K] @ W_packed — the serving fast path.

    packed: {"q": (K, N) int8/int16, "scale": (K/32, N) fp32}
    (``bbfp.pack_weight``). The weight side is consumed as stored — no
    per-call weight quantisation; only the activation is quantised (in VMEM
    on the kernel path). K-pad rows of q are zero, so padded K-blocks
    contribute exactly 0 whatever their (zero-padded) scale.
    """
    q, scale = packed["q"], packed["scale"]
    *lead, m_dim, k_dim = a.shape
    n_dim = q.shape[1]
    assert q.shape[0] == k_dim and scale.shape == (k_dim // B.DEFAULT_BLOCK, n_dim), (
        a.shape, q.shape, scale.shape)
    # a weight packed under a wider format (int16 folded ints) must never hit
    # the int8 MXU cast of a narrow fmt_name — catch the mismatch up front
    assert (q.dtype == jnp.int8) == (B.folded_max(B.parse_format(fmt_name)) <= 127), (
        f"packed dtype {q.dtype} inconsistent with {fmt_name}'s int8-path")
    a2 = a.reshape(-1, k_dim)
    rows = a2.shape[0]
    if not use_kernel or rows * n_dim < _MIN_KERNEL_ELEMS:
        out = B.bbfp_matmul_packed_ref(a2, q, scale, B.parse_format(fmt_name))
        return out.reshape(*lead, m_dim, n_dim)
    tm = _row_tile(rows)
    a2 = _pad_axis(_pad_axis(a2, tm, 0), 128, 1)
    q2 = _pad_axis(_pad_axis(q, 128, 0), 128, 1)
    s2 = _pad_axis(_pad_axis(scale, 128 // B.DEFAULT_BLOCK, 0), 128, 1)
    out = _matmul_packed_call(a2, q2, s2, fmt_name, tm=tm)[:rows, :n_dim]
    return out.reshape(*lead, m_dim, n_dim)


def lut_apply(x: jax.Array, fn_name: str, fmt_name: str = "BBFP(10,5)",
              use_kernel: bool = True) -> jax.Array:
    """Elementwise segmented-LUT f(x). Blocks of 32 run along the LAST dim of
    x (zero-padded tail block), matching the reference oracle exactly."""
    if not use_kernel or x.size < 8 * 512 or x.ndim == 0:
        return _ref.lut_apply_ref(x, fn_name, fmt_name)
    c = x.shape[-1]
    x2 = x.reshape(-1, c)
    rows = x2.shape[0]
    # pad C to a multiple of 32 (ref does the same inside _to_blocks), then to
    # the 512 tile width; extra zero-blocks are stripped after the call.
    x2 = _pad_axis(_pad_axis(x2, 32, 1), 512, 1)
    x2 = _pad_axis(x2, 8, 0)
    y = lut_apply_kernel(x2, fn_name, fmt_name, tr=8, tc=512)
    y = y[:rows, :c]
    return y.reshape(x.shape)
