"""jit'd public wrappers around the Pallas kernels.

These handle arbitrary leading dims + padding, pick interpret mode on CPU,
and fall back to the jnp reference when shapes are too small to tile (the
reference *is* the same arithmetic, so this is purely a dispatch decision).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bbfp as B
from repro.kernels import ref as _ref
from repro.kernels.bbfp_matmul import bbfp_matmul as _matmul_kernel_call
from repro.kernels.lut_nonlinear import lut_apply_kernel


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x


def bbfp_matmul(a: jax.Array, b: jax.Array, fmt_name: str = "BBFP(4,2)",
                use_kernel: bool = True) -> jax.Array:
    """C[..., M, N] = Q(a)[..., M, K] @ Q(b)[K, N] in BBFP arithmetic.

    K-block boundaries (32) align between the kernel's 128-wide K tiles and
    the reference's whole-K blocking, so kernel == ref exactly.
    """
    *lead, m_dim, k_dim = a.shape
    n_dim = b.shape[1]
    a2 = a.reshape(-1, k_dim)
    rows = a2.shape[0]
    if not use_kernel or rows * n_dim < 128 * 128:
        out = _ref.bbfp_matmul_ref(a2, b, fmt_name)
        return out.reshape(*lead, m_dim, n_dim)
    a2 = _pad_axis(_pad_axis(a2, 128, 0), 128, 1)
    b2 = _pad_axis(_pad_axis(b, 128, 0), 128, 1)
    out = _matmul_kernel_call(a2, b2, fmt_name)[:rows, :n_dim]
    return out.reshape(*lead, m_dim, n_dim)


def lut_apply(x: jax.Array, fn_name: str, fmt_name: str = "BBFP(10,5)",
              use_kernel: bool = True) -> jax.Array:
    """Elementwise segmented-LUT f(x). Blocks of 32 run along the LAST dim of
    x (zero-padded tail block), matching the reference oracle exactly."""
    if not use_kernel or x.size < 8 * 512 or x.ndim == 0:
        return _ref.lut_apply_ref(x, fn_name, fmt_name)
    c = x.shape[-1]
    x2 = x.reshape(-1, c)
    rows = x2.shape[0]
    # pad C to a multiple of 32 (ref does the same inside _to_blocks), then to
    # the 512 tile width; extra zero-blocks are stripped after the call.
    x2 = _pad_axis(_pad_axis(x2, 32, 1), 512, 1)
    x2 = _pad_axis(x2, 8, 0)
    y = lut_apply_kernel(x2, fn_name, fmt_name, tr=8, tc=512)
    y = y[:rows, :c]
    return y.reshape(x.shape)
