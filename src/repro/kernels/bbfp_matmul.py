"""Pallas TPU kernel: BBFP block-quantised matmul (the PE-array analogue).

TPU adaptation of the paper's weight-stationary BBFP PE array (§IV.A):

  * the 4x4 PE block becomes a (TM, TN) = (128, 128) MXU-aligned output tile;
  * the per-block shared exponent lives on K-blocks of 32 (paper's BlockSize,
    = VPU lane width); quantisation of both operands happens *inside* the
    kernel, in VMEM, so HBM only ever sees the fp source once;
  * Eq. 10's flag-aware mantissa multiply + shift is folded into the stored
    integer (q = m << (shift*flag)), so each K-block contributes one int8xint8
    -> int32 MXU matmul (exact), scaled by the two power-of-two shared
    exponents (Eq. 7) and accumulated in an fp32 VMEM scratch — the paper's
    "FP adder" for inter-block partial sums;
  * the paper's carry-chain sparse adder has no MXU analogue (documented in
    DESIGN.md); its spirit — never spill partial sums — is kept by
    accumulating across the K grid dimension in VMEM scratch.

Two kernel variants map the two halves of Table I's dataflow:

  * ``bbfp_matmul``        — both operands arrive fp and are quantised in
    VMEM.  This is the *training/prefill* shape of the PE array, where the
    weight tile changes every step.
  * ``bbfp_matmul_packed`` — the WEIGHT-STATIONARY serving path.  The paper's
    PE array holds weights pre-aligned as mantissas + shared exponents
    (Table I); here the weight operand arrives already integer-decomposed
    (``bbfp.pack_weight``: q int8/int16 (K, N), power-of-two scale
    (K/32, N)) and goes STRAIGHT to the int8xint8 -> int32 MXU dot — no
    weight quantisation in the HLO, and HBM streams 9 bits/elt of weight
    (int8 codes + one fp32 scale per 32; Table I's 5-bit-exponent ideal is
    8.16) instead of 16 — a ~1.8x weight-read cut, real, not just storage.
    Only the activation side is quantised in VMEM, exactly as the paper's
    input-side BFP2BBFP converter feeds the array.

Both validated against ``ref.bbfp_matmul_ref`` in interpret mode (CPU); the
packed variant is additionally bit-exact vs the fp variant (same quantiser,
same block order — tested in tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bbfp as B

KBLOCK = B.DEFAULT_BLOCK  # 32


def _exponent_tile(x):
    """floor(log2|x|) for fp32 x via bit tricks (no frexp in Mosaic).

    Matches ``core.bbfp._exponent`` exactly on every edge class (tested in
    tests/test_bbfp_format.py): ±0 and subnormals clip to _EXP_MIN (the
    raw biased field reads 0 -> -127), |x| >= 2^15 saturates the 5-bit
    shared exponent at _EXP_MAX, and inf/nan (biased field 255 -> +128)
    clip to _EXP_MAX — so the kernel and the oracle pick identical shared
    exponents instead of silently diverging on extreme inputs."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    e = jnp.where(x == 0.0, B._EXP_MIN, e)
    return jnp.clip(e, B._EXP_MIN, B._EXP_MAX)


def _quantize_kblocks(x, m: int, o: int, kind: str):
    """Quantise (R, TK) tile along K in blocks of KBLOCK.

    Returns (q, scale): q int32 (R, TK) with flag folded in (sign applied),
    scale fp32 (R, TK//KBLOCK) power of two such that x ~= q * scale per block.
    """
    r, tk = x.shape
    nb = tk // KBLOCK
    xb = x.reshape(r, nb, KBLOCK).astype(jnp.float32)
    if kind == "int":
        # symmetric absmax int baseline (float per-block scale)
        amax = jnp.max(jnp.abs(xb), axis=-1)
        scale = jnp.where(amax == 0, 1.0, amax / (2 ** (m - 1) - 1))
        q = jnp.clip(jnp.round(xb / scale[..., None]),
                     -(2 ** (m - 1) - 1), 2 ** (m - 1) - 1)
        return q.reshape(r, tk).astype(jnp.int32), scale
    e = _exponent_tile(xb)
    e_max = jnp.max(e, axis=-1)
    if kind == "bfp":
        e_s = e_max
        flag = jnp.zeros_like(e)
        shift = 0
    else:
        shift = m - o
        e_s = jnp.clip(e_max - shift, B._EXP_MIN, B._EXP_MAX)
        flag = (e > e_s[..., None]).astype(jnp.int32)
    step = jnp.exp2((e_s[..., None] - m + 1 + flag * shift).astype(jnp.float32))
    q = jnp.clip(jnp.round(jnp.abs(xb) / step), 0, 2**m - 1)
    q = jnp.where(xb < 0, -q, q) * jnp.exp2((flag * shift).astype(jnp.float32))
    scale = jnp.exp2((e_s - m + 1).astype(jnp.float32))
    return q.reshape(r, tk).astype(jnp.int32), scale


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, m, o, kind, n_k, int8_path):
    """Grid = (M/TM, N/TN, K/TK); K innermost for accumulation."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    bT = b_ref[...].T  # (TN, TK): quantise B along its K dim
    qa, sa = _quantize_kblocks(a, m, o, kind)       # (TM, TK), (TM, nb)
    qb, sb = _quantize_kblocks(bT, m, o, kind)      # (TN, TK), (TN, nb)
    tk = a.shape[-1]
    nb = tk // KBLOCK
    acc = acc_ref[...]
    for blk in range(nb):
        sl = slice(blk * KBLOCK, (blk + 1) * KBLOCK)
        if int8_path:
            # int8 x int8 -> int32 MXU dot (exact for |q| <= 127)
            prod = jax.lax.dot_general(
                qa[:, sl].astype(jnp.int8), qb[:, sl].astype(jnp.int8),
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32)
            prod = prod.astype(jnp.float32)
        else:
            prod = jax.lax.dot_general(
                qa[:, sl].astype(jnp.float32), qb[:, sl].astype(jnp.float32),
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        acc = acc + prod * sa[:, blk][:, None] * sb[:, blk][None, :]
    acc_ref[...] = acc

    @pl.when(k_idx == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fmt_name", "tm", "tn", "tk", "interpret"))
def bbfp_matmul(a: jax.Array, b: jax.Array, fmt_name: str = "BBFP(4,2)",
                tm: int = 128, tn: int = 128, tk: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """C = Q(a) @ Q(b) with in-kernel BBFP quantisation of both operands.

    a: (M, K) fp, b: (K, N) fp. M, N, K must be multiples of the tile sizes
    (the ops.py wrapper pads).
    """
    fmt = B.parse_format(fmt_name)
    m_, k_ = a.shape
    k2_, n_ = b.shape
    assert k_ == k2_ and m_ % tm == 0 and n_ % tn == 0 and k_ % tk == 0, (
        (a.shape, b.shape, tm, tn, tk))
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n_k = k_ // tk
    int8_path = B.folded_max(fmt) <= 127
    kernel = functools.partial(
        _matmul_kernel, m=fmt.mantissa, o=fmt.overlap, kind=fmt.kind,
        n_k=n_k, int8_path=int8_path)
    grid = (m_ // tm, n_ // tn, n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_, n_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(a, b)


def _matmul_packed_kernel(a_ref, qw_ref, sw_ref, o_ref, acc_ref, *,
                          m, o, kind, n_k, int8_path):
    """Weight-stationary variant: the weight tile arrives pre-packed
    (qw int8/int16 (TK, TN), sw fp32 (TK/KBLOCK, TN)) and feeds the MXU dot
    directly; only the activation tile is quantised in VMEM. The per-block
    accumulation (prod * sa * sw) mirrors ``_matmul_kernel`` op-for-op so the
    two paths are bit-identical when the packed ints match the in-kernel
    quantiser's (pack_weight uses the same arithmetic; tested)."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    qa, sa = _quantize_kblocks(a, m, o, kind)       # (TM, TK), (TM, nb)
    qw = qw_ref[...]                                # (TK, TN) int
    sw = sw_ref[...]                                # (TK//KBLOCK, TN) fp32
    tk = a.shape[-1]
    nb = tk // KBLOCK
    acc = acc_ref[...]
    for blk in range(nb):
        sl = slice(blk * KBLOCK, (blk + 1) * KBLOCK)
        if int8_path:
            # int8 x int8 -> int32 MXU dot (exact for |q| <= 127)
            prod = jax.lax.dot_general(
                qa[:, sl].astype(jnp.int8), qw[sl, :].astype(jnp.int8),
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
            prod = prod.astype(jnp.float32)
        else:
            prod = jax.lax.dot_general(
                qa[:, sl].astype(jnp.float32), qw[sl, :].astype(jnp.float32),
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        acc = acc + prod * sa[:, blk][:, None] * sw[blk][None, :]
    acc_ref[...] = acc

    @pl.when(k_idx == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fmt_name", "tm", "tn", "tk", "interpret"))
def bbfp_matmul_packed(a: jax.Array, qw: jax.Array, sw: jax.Array,
                       fmt_name: str = "BBFP(4,2)",
                       tm: int = 128, tn: int = 128, tk: int = 128,
                       interpret: bool | None = None) -> jax.Array:
    """C = Q(a) @ W_packed with the weight already stored as aligned
    mantissas + shared exponents (``bbfp.pack_weight``).

    a: (M, K) fp; qw: (K, N) int8/int16 with the flag folded in;
    sw: (K/KBLOCK, N) fp32 power-of-two per-block scales. M, N, K must be
    multiples of the tile sizes (the ops.py wrapper pads; K-pad rows of qw
    are zero so padded blocks contribute exactly 0)."""
    fmt = B.parse_format(fmt_name)
    m_, k_ = a.shape
    k2_, n_ = qw.shape
    assert k_ == k2_ and sw.shape == (k_ // KBLOCK, n_), (a.shape, qw.shape, sw.shape)
    assert m_ % tm == 0 and n_ % tn == 0 and k_ % tk == 0, (
        (a.shape, qw.shape, tm, tn, tk))
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n_k = k_ // tk
    int8_path = B.folded_max(fmt) <= 127
    kernel = functools.partial(
        _matmul_packed_kernel, m=fmt.mantissa, o=fmt.overlap, kind=fmt.kind,
        n_k=n_k, int8_path=int8_path)
    grid = (m_ // tm, n_ // tn, n_k)
    nb = tk // KBLOCK
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
            pl.BlockSpec((nb, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_, n_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(a, qw, sw)
