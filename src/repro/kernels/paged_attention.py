"""Pallas TPU kernel: fused paged attention over PACKED BBFP KV pages.

The unfused serving path runs decode attention as three separate XLA ops:
gather the slot's pages through the block table, dequantise the whole view
to bf16, then score/softmax/combine — so the packed-KV bandwidth win of
PR 3 is partly handed back as extra HBM round trips (the dequantised view
is 2x/4x the bytes of the storage it came from). This kernel does all of
it in one VMEM-resident pass: the grid walks the block table one PAGE per
K step (a page is exactly one 32-row BBFP quantisation block), the page's
int8 codes + shared exponents are DMA'd directly into VMEM via a
scalar-prefetch index map, decoded in registers (one mask/shift + exp2
multiply, the ``bbfp.unpack_kv`` arithmetic), and consumed by the same
flash online-softmax loop as ``flash_lut_attention`` — K/V never exist in
HBM at bf16 width.

Semantics contract (parity-tested against the jnp fallback):
  * sentinel block-table entries (= n_pages) CLAMP to the last page in the
    index map, exactly like the jnp gather's out-of-bounds clamp; the
    per-slot position mask then discards those rows — identical to
    ``attention._paged_view`` + the decode-branch mask;
  * per-row query positions qp = pos[b] + row//G cover q_len=1 decode and
    q_len=chunk incremental prefill with the same kernel (causal within
    the chunk, since earlier chunk rows were scattered before attention);
  * validity is (k_pos <= qp) & (k_pos > qp - window) — the decode
    branch's ``eff_window`` mask, windows included;
  * fully-dead page tiles (first k_pos past every query row) are skipped
    via ``pl.when`` — the running max/sum/acc are simply not touched,
    which is bitwise what masking them would produce.

Storage modes: ``nibble=False`` reads the int8-code pools of
``storage="packed"`` ({"q": (P,page,KH,hd), "exp": (P,page,KH,ceil(hd/32))});
``nibble=True`` reads ``storage="packed4"`` pools whose q leaf carries TWO
sign-magnitude nibble codes per byte (``bbfp.pack_kv_nibble``, hd/2 bytes
per row) — sub-byte KV that is ONLY ever decoded here.

The softmax exp comes from ``jnp.exp`` when qcfg.nonlinear is "none"
(greedy-token-identical to the unfused fp32 softmax at fp32 compute) or
from the segmented-LUT exp unit (``flash_lut_attention._lut_exp_tile``)
when a nonlinear format is set — then the online rescale makes it
close-to rather than bitwise-equal-to the unfused full-row LUT softmax,
same caveat as the chunked-prefill path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bbfp as B
from repro.core import nonlinear as NL
from repro.kernels.flash_lut_attention import NEG, _lut_exp_tile


def _decode_tile(codes, exp, *, fmt: B.QuantFormat, nibble: bool,
                 hd: int, out_dtype) -> jax.Array:
    """(page, hd) fp tile from one page's codes (page, hdq) + exponents
    (page, nb). Register-level ``unpack_kv``/``unpack_kv_nibble``: mask,
    shift, exp2 multiply — the int8 bytes are the only thing DMA'd."""
    m, shift = fmt.mantissa, (fmt.shift if fmt.kind == "bbfp" else 0)
    page = codes.shape[0]
    c = codes.astype(jnp.int32)
    if nibble:
        b = c & 0xFF
        c = jnp.stack([b & 0xF, (b >> 4) & 0xF], axis=-1).reshape(page, hd)
        mag = c & 7
        neg = (c & 8) != 0
    else:
        mag = jnp.abs(c)
        neg = c < 0
    mant = mag & (2**m - 1)
    flag = mag >> m
    e = exp.astype(jnp.int32)                               # (page, nb)
    nb = e.shape[-1]
    e = jnp.broadcast_to(e[:, :, None],
                         (page, nb, B.DEFAULT_BLOCK)).reshape(page, -1)[:, :hd]
    step_log2 = e - m + 1 + flag * shift
    v = jnp.where(neg, -mant, mant).astype(jnp.float32) \
        * jnp.exp2(step_log2.astype(jnp.float32))
    return v.astype(out_dtype)


def _paged_kernel(bt_ref, pos_ref, win_ref,                     # scalar prefetch
                  q_ref, kq_ref, ke_ref, vq_ref, ve_ref, tab_ref,
                  *refs,
                  fmt, nibble, scale, s, g, hd, page, n_k, n_pages,
                  compute_dtype, lut, exp_lo, partials):
    if partials:
        o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    j = pl.program_id(2)
    rows = s * g
    pos = pos_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # a page tile whose first row is past the LAST query row is fully
    # masked: skip its dequant + dot entirely (the scratch state is
    # bitwise-unchanged either way). Tile j=0 is always live (pos >= 0).
    # In partials mode a SENTINEL table entry also kills its tile: under
    # page-dim sharding a non-local (translated-to-sentinel) entry can sit
    # at a position-live slot of the table, and the clamped page it would
    # read belongs to some other sequence — the merge combines only tiles
    # this shard actually owns. (Without sharding the two conditions agree
    # for every live slot: pages up through pos+s-1 are always allocated.)
    live = j * page <= pos + (s - 1)
    if partials:
        live = live & (bt_ref[b, j] < n_pages)

    @pl.when(live)
    def _tile():
        q = q_ref[0, :, 0].reshape(rows, hd).astype(jnp.float32)
        k = _decode_tile(kq_ref[0, :, 0], ke_ref[0, :, 0], fmt=fmt,
                         nibble=nibble, hd=hd, out_dtype=compute_dtype)
        sc = jax.lax.dot_general(q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        kp = j * page + jax.lax.broadcasted_iota(jnp.int32, (rows, page), 1)
        qp = pos + jax.lax.broadcasted_iota(jnp.int32, (rows, page), 0) // g
        valid = (kp <= qp) & (kp > qp - win_ref[0])
        sc = jnp.where(valid, sc, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        if lut is None:
            p = jnp.exp(sc - m_new[:, None])
        else:
            shifted = jnp.maximum(sc - m_new[:, None], exp_lo)
            p = _lut_exp_tile(shifted, tab_ref[...], **lut)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        v = _decode_tile(vq_ref[0, :, 0], ve_ref[0, :, 0], fmt=fmt,
                         nibble=nibble, hd=hd, out_dtype=compute_dtype)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(compute_dtype).astype(jnp.float32), v.astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _done():
        if partials:
            # flash-decoding partials: the UNNORMALISED accumulator plus the
            # running (max, sum) — ``merge_partials`` finishes the softmax
            # after combining shards over the page axis
            o_ref[0, :, 0] = acc_ref[...].reshape(s, g, hd).astype(o_ref.dtype)
            mo_ref[0, :, 0] = m_ref[...].reshape(s, g)
            lo_ref[0, :, 0] = l_ref[...].reshape(s, g)
        else:
            out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
            o_ref[0, :, 0] = out.reshape(s, g, hd).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "nibble", "exp_fmt", "interpret",
                                    "partials"))
def paged_attention(q: jax.Array, k_pool: dict, v_pool: dict,
                    block_table: jax.Array, pos: jax.Array,
                    window: jax.Array, *, fmt: B.QuantFormat,
                    nibble: bool = False, exp_fmt: B.QuantFormat | None = None,
                    interpret: bool | None = None, partials: bool = False):
    """out (B,S,KH,G,hd) = paged flash attention of q against packed pools.

    q: (B, S, KH, G, hd) in the compute dtype; k_pool/v_pool: {"q","exp"}
    int8 page pools (``paged_kv`` storage="packed"/"packed4"); block_table:
    (B, max_pages) int32 (sentinel = n_pages); pos: (B,) int32 per-slot
    write offsets of row 0 (this call's rows are already scattered);
    window: int32 scalar, the decode branch's eff_window (traced OK).
    exp_fmt: LUT format for the in-kernel exp (qcfg.nonlinear), None = fp.
    partials=True returns the flash-decoding partials instead of the
    normalised output: ``(acc, m, l)`` with acc (B,S,KH,G,hd) fp32
    UNNORMALISED, m/l (B,S,KH,G) fp32 running max/sum — the sequence-
    parallel page-dim sharding runs this per shard over its LOCAL pool
    (sentinel entries skip their tile entirely, so a shard only
    accumulates pages it owns; an all-sentinel row yields m=-inf, l=0)
    and ``merge_partials`` log-sum-exp-combines the shards.
    """
    bsz, s, kh, g, hd = q.shape
    n_pages, page = k_pool["q"].shape[0], k_pool["q"].shape[1]
    n_k = block_table.shape[1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    hdq = hd // 2 if nibble else hd
    nb = k_pool["exp"].shape[-1]
    assert k_pool["q"].shape == (n_pages, page, kh, hdq), k_pool["q"].shape
    win = jnp.asarray(window, jnp.int32).reshape(1)

    if exp_fmt is None:
        lut, table = None, jnp.zeros((1, 1, 1, 1), jnp.float32)
    else:
        spec = NL.get_lut("exp", exp_fmt)
        lut = dict(m=exp_fmt.mantissa, o=exp_fmt.overlap, e_min=spec.e_min,
                   a_bits=NL.ADDRESS_BITS)
        table = spec.table

    def page_idx(b, h, j, bt, _pos, _win):
        # sentinel (= n_pages) clamps to the last page, like the jnp gather;
        # the position mask discards those rows
        return (jnp.minimum(bt[b, j], n_pages - 1), 0, h, 0)

    kernel = functools.partial(
        _paged_kernel, fmt=fmt, nibble=nibble,
        scale=float(1.0 / np.sqrt(np.float32(hd))), s=s, g=g, hd=hd,
        page=page, n_k=n_k, n_pages=n_pages, compute_dtype=q.dtype, lut=lut,
        exp_lo=NL.EXP_LUT_RANGE, partials=partials)
    out_spec = pl.BlockSpec((1, s, 1, g, hd),
                            lambda b, h, j, *_: (b, 0, h, 0, 0))
    if partials:
        ml_spec = pl.BlockSpec((1, s, 1, g), lambda b, h, j, *_: (b, 0, h, 0))
        out_specs = [out_spec, ml_spec, ml_spec]
        out_shape = [
            jax.ShapeDtypeStruct((bsz, s, kh, g, hd), jnp.float32),  # acc
            jax.ShapeDtypeStruct((bsz, s, kh, g), jnp.float32),      # m
            jax.ShapeDtypeStruct((bsz, s, kh, g), jnp.float32),      # l
        ]
    else:
        out_specs, out_shape = out_spec, jax.ShapeDtypeStruct(
            (bsz, s, kh, g, hd), q.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(bsz, kh, n_k),
        in_specs=[
            pl.BlockSpec((1, s, 1, g, hd),
                         lambda b, h, j, *_: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, page, 1, hdq), page_idx),
            pl.BlockSpec((1, page, 1, nb), page_idx),
            pl.BlockSpec((1, page, 1, hdq), page_idx),
            pl.BlockSpec((1, page, 1, nb), page_idx),
            pl.BlockSpec(table.shape, lambda b, h, j, *_: (0, 0, 0, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((s * g,), jnp.float32),       # running max
            pltpu.VMEM((s * g,), jnp.float32),       # running sum
            pltpu.VMEM((s * g, hd), jnp.float32),    # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_table.astype(jnp.int32), jnp.asarray(pos, jnp.int32), win,
      q, k_pool["q"], k_pool["exp"], v_pool["q"], v_pool["exp"], table)
    if partials:
        acc, m, l = out
        return acc, m, l
    return out


def merge_partials(acc: jax.Array, m: jax.Array, l: jax.Array, *,
                   axis_name: str | None = None,
                   eps: float = 1e-30) -> jax.Array:
    """Finish the flash-decoding softmax from per-shard partials.

    acc: (..., hd) fp32 UNNORMALISED accumulator; m, l: (...) fp32 running
    max / sum, as returned by ``paged_attention(..., partials=True)``.

    Two modes:
      * ``axis_name`` set — inside ``shard_map``: pmax/psum the log-sum-exp
        combine over the named (page) mesh axis, each shard returning the
        identical merged output.
      * ``axis_name`` None — reference mode: the partials carry an extra
        LEADING shard axis (stacked), reduced with plain max/sum. Used by
        the unit tests to check the distributed merge against one device.

    A shard whose slot saw no live pages carries m = -inf, l = 0, acc = 0;
    ``exp(m - m_global)`` would be exp(-inf - -inf) = NaN when EVERY shard
    is dead (padding rows), so the scale is forced to 0 there — dead slots
    come out as zeros, matching the unsharded kernel's masked rows.

    With one shard this reduces to acc / max(l, eps) exactly (scale =
    exp(0) = 1): bitwise-identical to the kernel's own normalisation.
    """
    if axis_name is not None:
        m_g = jax.lax.pmax(m, axis_name)
        scale = jnp.where(m == -jnp.inf, 0.0, jnp.exp(m - m_g))
        l_g = jax.lax.psum(l * scale, axis_name)
        acc_g = jax.lax.psum(acc * scale[..., None], axis_name)
    else:
        m_g = jnp.max(m, axis=0)
        scale = jnp.where(m == -jnp.inf, 0.0, jnp.exp(m - m_g[None]))
        l_g = jnp.sum(l * scale, axis=0)
        acc_g = jnp.sum(acc * scale[..., None], axis=0)
    return acc_g / jnp.maximum(l_g, eps)[..., None]
