"""Pallas TPU kernels for the BBFP hot spots (validated in interpret mode).

bbfp_matmul         — block-quantised matmul, the PE-array analogue (int8 MXU)
lut_nonlinear       — exponent-segmented LUT elementwise apply (nonlinear unit)
flash_lut_attention — flash attention with the Fig. 6 LUT softmax fused into
                      the VMEM tile loop (scores never touch HBM)
ops                 — public jit wrappers;  ref — pure-jnp oracles
"""
from repro.kernels.ops import bbfp_matmul, lut_apply  # noqa: F401
from repro.kernels.flash_lut_attention import flash_lut_attention  # noqa: F401
