"""Pallas TPU kernel: exponent-segmented LUT nonlinear apply (§IV.B).

The whole table bank (2 signs x 2 flags x 32 exponents x 128 addresses fp32
= 64 KiB) fits in VMEM, so the paper's "load the sub-table for the block's
shared exponent from external memory, pipelined" becomes: the table rides in
as a whole-array BlockSpec block (grid-invariant -> fetched once), and each
(8, 128)-lane data tile does quantise -> composite-index -> in-VMEM gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bbfp as B
from repro.core import nonlinear as NL


def _lut_kernel(x_ref, tab_ref, o_ref, *, m, o, kind, e_min, a_bits):
    x = x_ref[...].astype(jnp.float32)
    r, c = x.shape
    nb = c // B.DEFAULT_BLOCK
    xb = x.reshape(r, nb, B.DEFAULT_BLOCK)
    bits = jax.lax.bitcast_convert_type(xb, jnp.int32)
    e = jnp.where(xb == 0.0, B._EXP_MIN, ((bits >> 23) & 0xFF) - 127)
    e = jnp.clip(e, B._EXP_MIN, B._EXP_MAX)
    e_max = jnp.max(e, axis=-1)
    shift = (m - o) if kind == "bbfp" else 0
    e_s = jnp.clip(e_max - shift, B._EXP_MIN, B._EXP_MAX)
    flag = (e > e_s[..., None]).astype(jnp.int32) if kind == "bbfp" else jnp.zeros_like(e)
    step = jnp.exp2((e_s[..., None] - m + 1 + flag * shift).astype(jnp.float32))
    q = jnp.clip(jnp.round(jnp.abs(xb) / step), 0, 2**m - 1).astype(jnp.int32)
    addr = q >> (m - a_bits)
    sign_idx = (xb < 0).astype(jnp.int32)
    n_exp = tab_ref.shape[2]
    n_addr = tab_ref.shape[3]
    e_idx = jnp.clip(e_s[..., None] - e_min, 0, n_exp - 1)
    comp = ((sign_idx * 2 + flag) * n_exp + e_idx) * n_addr + addr
    flat = tab_ref[...].reshape(-1)
    y = jnp.take(flat, comp.reshape(r, c), axis=0)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fn_name", "fmt_name", "tr", "tc", "interpret"))
def lut_apply_kernel(x: jax.Array, fn_name: str = "exp",
                     fmt_name: str = "BBFP(10,5)",
                     tr: int = 8, tc: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """Elementwise f(x) via the segmented LUT. x: (R, C), C % block == 0.
    The ops.py wrapper handles reshaping/padding of arbitrary tensors."""
    fmt = B.parse_format(fmt_name)
    spec = NL.get_lut(fn_name, fmt)
    r_, c_ = x.shape
    assert r_ % tr == 0 and c_ % tc == 0 and tc % B.DEFAULT_BLOCK == 0, (x.shape, tr, tc)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    kernel = functools.partial(
        _lut_kernel, m=fmt.mantissa, o=fmt.overlap, kind=fmt.kind,
        e_min=spec.e_min, a_bits=NL.ADDRESS_BITS)
    grid = (r_ // tr, c_ // tc)
    tab = spec.table
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec(tab.shape, lambda i, j: (0, 0, 0, 0)),  # whole table, VMEM-resident
        ],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r_, c_), x.dtype),
        interpret=interpret,
    )(x, tab)
