"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bbfp as B
from repro.core import nonlinear as NL


def bbfp_matmul_ref(a: jax.Array, b: jax.Array, fmt_name: str = "BBFP(4,2)") -> jax.Array:
    """Block-quantise both operands along K, then exact fp32 matmul of the
    dequantised values — identical arithmetic to the kernel's scaled integer
    dot (both are exact in fp32 for our mantissa ranges)."""
    fmt = B.parse_format(fmt_name)
    return B.bbfp_matmul_ref(a, b, fmt)


def lut_apply_ref(x: jax.Array, fn_name: str = "exp",
                  fmt_name: str = "BBFP(10,5)") -> jax.Array:
    fmt = B.parse_format(fmt_name)
    return NL.lut_apply(x, NL.get_lut(fn_name, fmt))


def quantize_ref(x: jax.Array, fmt_name: str = "BBFP(4,2)"):
    """Blocked int decomposition oracle: returns (q, scale)."""
    fmt = B.parse_format(fmt_name)
    return B.to_int_repr(x, fmt)
