"""Deterministic synthetic-text data pipeline.

No datasets ship offline, so the training substrate generates *learnable*
token streams: a fixed random bigram chain with Zipfian marginals plus a
copy task (period-8 repeats), so cross-entropy falls well below the uniform
log V and quantisation-induced degradation is measurable (Table II proxy).

Production notes (and what is actually implemented):
  * deterministic: batch at step s is a pure function of (seed, step) — a
    restarted/elastic job regenerates the identical stream (tested);
  * host-sharded: each process materialises only its slice of the global
    batch (process_index/process_count plumbed; ==1 in this container);
  * device layout: the iterator yields numpy; the train step's in_shardings
    moves it to the ("pod","data") batch axes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.3

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        # Zipfian unigram over a permuted alphabet
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        probs /= probs.sum()
        self._unigram = probs[rng.permutation(v)]
        # sparse bigram: each token has 4 likely successors (structure to learn)
        self._succ = rng.integers(0, v, size=(v, 4))

    def batch(self, step: int, batch_size: int, *, process_index: int = 0,
              process_count: int = 1) -> dict:
        """Global batch for `step`, sliced for this process."""
        assert batch_size % process_count == 0
        local = batch_size // process_count
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + process_index)
        s = self.seq_len + 1
        toks = np.empty((local, s), np.int64)
        toks[:, 0] = rng.choice(self.vocab, size=local, p=self._unigram)
        for t in range(1, s):
            # 85%: bigram successor; 15%: unigram resample
            pick = rng.integers(0, 4, size=local)
            bigram = self._succ[toks[:, t - 1], pick]
            fresh = rng.choice(self.vocab, size=local, p=self._unigram)
            use_bigram = rng.random(local) < 0.85
            toks[:, t] = np.where(use_bigram, bigram, fresh)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_batch_iterator(dataset: SyntheticLMDataset, batch_size: int,
                        start_step: int = 0, **kw):
    """Infinite deterministic iterator resumable at any step."""
    step = start_step
    while True:
        yield step, dataset.batch(step, batch_size, **kw)
        step += 1
