from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, adamw_init, adamw_update, cosine_lr, clip_by_global_norm,
)
from repro.optim.compression import (  # noqa: F401
    compression_init, compress_gradients,
)
