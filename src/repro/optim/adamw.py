"""AdamW (fp32 master moments), global-norm clipping, cosine schedule."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 20
    total_steps: int = 1000


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def cosine_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def clip_by_global_norm(grads, max_norm):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
