"""Gradient compression for the cross-pod all-reduce: int8 block quantisation
(using the paper's own BBFP machinery!) with error feedback.

The pod axis carries a full gradient all-reduce once per step; compressing
it 4x (fp32->int8-mantissa BBFP) cuts the inter-pod collective term of the
roofline. Error feedback keeps the scheme unbiased over time: the residual
(g - Q(g)) is added back before the next step's quantisation, which is the
standard EF-SGD trick and is what makes 8-bit all-reduce converge.

On this 1-process container the collective itself is a no-op; the
quantise -> (all-reduce) -> dequantise + EF path is exercised and tested
for convergence on the tiny LM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bbfp as B

_FMT = B.QuantFormat("bbfp", 6, 3)   # int8-safe after flag folding? 504 -> int16;
_FMT8 = B.QuantFormat("int", 8)      # wire format for the all-reduce


def compression_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q(g):
    return B.fake_quant(g.astype(jnp.float32), _FMT8, axis=-1)


def compress_gradients(grads, error_state, psum_fn=None):
    """Returns (decompressed grads as seen post-allreduce, new error state).

    psum_fn: the collective to run on the compressed representation (e.g.
    functools.partial(jax.lax.pmean, axis_name='pod') inside shard_map);
    None = single-replica identity."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q = _q(g32)
        if psum_fn is not None:
            q = psum_fn(q)
        return q, g32 - _q(g32)   # residual of the *local* quantisation

    out = jax.tree.map(one, grads, error_state)
    newg = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newe = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return newg, newe
