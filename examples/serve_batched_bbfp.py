"""Serving example: batched prefill+decode with the full BBAL stack —
BBFP(4,2) linears and the BBFP(10,5) segmented-LUT nonlinear unit — an
accuracy check of the quantised server against the fp server, a ragged
continuous-batching run (staggered prompt lengths sharing ONE jitted decode
per tick via the per-slot position cache), a shared-system-prompt workload
through the radix prefix cache (common 64-token prefix stored once as
copy-on-write pages; followers chunk-prefill only their unique suffix,
admitted together through ONE batched multi-slot prefill shape), and an
OVERSUBSCRIBED page pool served via preemption + recompute-on-readmit —
token-identical to the unconstrained run.

  PYTHONPATH=src python examples/serve_batched_bbfp.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.serve import generate
from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime.batcher import ContinuousBatcher, Request


def main():
    cfg = configs.get("llama7b").tiny_lm_config(vocab=256)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    prompts = jax.random.randint(key, (4, 24), 0, cfg.vocab)

    fp = generate(cfg, params, prompts, Q.FP, gen_len=12)
    paper = generate(cfg, params, prompts, Q.PAPER, gen_len=12)
    bfp = generate(cfg, params, prompts,
                   Q.QuantConfig(linear="BFP4", nonlinear="BFP10"), gen_len=12)

    agree = lambda a, b: float(jnp.mean((a == b).astype(jnp.float32)))
    print("batched greedy decode, 4 prompts x 12 tokens")
    print(f"  fp       : {fp[0].tolist()}")
    print(f"  BBAL     : {paper[0].tolist()}   agreement {agree(fp, paper):.0%}")
    print(f"  BFP4/10  : {bfp[0].tolist()}   agreement {agree(fp, bfp):.0%}")
    print("(BBAL = BBFP(4,2) linears + BBFP(10,5) LUT nonlinear unit)")

    # ragged continuous batching: staggered prompt lengths coexist in one
    # decode batch — the per-slot position cache keeps it to 1 call/tick
    bat = ContinuousBatcher(cfg, params, Q.PAPER, n_slots=3, max_len=64)
    ragged = [jax.random.randint(jax.random.fold_in(key, i), (8 + 5 * i,),
                                 0, cfg.vocab) for i in range(3)]
    for i, p in enumerate(ragged):
        bat.submit(Request(rid=i, prompt=p, max_new=8))
    finished, ticks = bat.run()
    print(f"ragged continuous batching: {len(finished)} requests "
          f"(prompt lens {[int(p.shape[0]) for p in ragged]}) in {ticks} ticks, "
          f"{bat.decode_calls} jitted decode calls (one per tick)")

    # shared-system-prompt workload: every request opens with the same
    # 64-token "system prompt" (2 full 32-row pages). The first admission
    # computes and registers those pages; the other three map them into
    # their block tables (refcount++), store NOTHING extra for them, and
    # chunk-prefill only their unique suffix — same tokens as if each
    # request had been served alone.
    system = jax.random.randint(jax.random.fold_in(key, 77), (64,), 0, cfg.vocab)
    bat2 = ContinuousBatcher(cfg, params, Q.PAPER, n_slots=4, max_len=128)
    for i in range(4):
        sfx = jax.random.randint(jax.random.fold_in(key, 80 + i),
                                 (6 + 4 * i,), 0, cfg.vocab)
        bat2.submit(Request(rid=i, prompt=jnp.concatenate([system, sfx]),
                            max_new=8))
    bat2.step()                     # all four admitted: peak sharing
    stats = bat2.kv_stats()
    finished2, _ = bat2.run()
    print(f"shared system prompt (64 tokens x 4 requests): "
          f"{len(finished2)} served, prefix hit rate "
          f"{bat2.prefix_hit_rate:.0%}, "
          f"{stats['pages_shared']} pages shared "
          f"({stats['kv_bytes_physical']} physical vs "
          f"{stats['kv_bytes_logical']} logical KV bytes), "
          f"{bat2.chunk_prefill_calls} prefill chunks in "
          f"{bat2.prefill_steps} batched steps with "
          f"{bat2.prefill_traces} compiled shape "
          f"(no sharing would need {4 * 3} chunks)")

    # oversubscribed pool: three requests whose worst case totals 9 pages
    # share a 6-page pool. The engine admits them all (prompt pages only),
    # preempts the lowest-priority sequence when decode appends exhaust the
    # pool, and recomputes it on readmission — greedy decode makes the
    # outputs token-identical to an unconstrained pool.
    prompts3 = [jnp.concatenate([system[:32], jax.random.randint(
        jax.random.fold_in(key, 90 + i), (9 + 4 * i,), 0, cfg.vocab)])
        for i in range(3)]
    outs = {}
    for n_pages in (None, 6):
        bat3 = ContinuousBatcher(cfg, params, Q.PAPER, n_slots=3,
                                 max_len=128, n_pages=n_pages, preempt=True)
        for i, p in enumerate(prompts3):
            bat3.submit(Request(rid=i, prompt=p, max_new=28))
        done, _ = bat3.run()
        outs[n_pages] = {r.rid: r.out_tokens for r in done}
        if n_pages:
            print(f"oversubscribed pool ({n_pages} pages for 9 worst-case): "
                  f"{len(done)} served with {bat3.preemptions} preemptions, "
                  f"{bat3.recomputed_tokens} tokens recomputed on readmit, "
                  f"radix kept {bat3.kv_stats()['radix_pages']} pages "
                  f"indexed")
    print("preempted run token-identical to unconstrained:",
          outs[None] == outs[6])


if __name__ == "__main__":
    main()
