"""Serving example: batched prefill+decode with the full BBAL stack —
BBFP(4,2) linears and the BBFP(10,5) segmented-LUT nonlinear unit — and an
accuracy check of the quantised server against the fp server.

  PYTHONPATH=src python examples/serve_batched_bbfp.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.serve import generate
from repro.models import model as M
from repro.quant import linear as Q


def main():
    cfg = configs.get("llama7b").tiny_lm_config(vocab=256)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    prompts = jax.random.randint(key, (4, 24), 0, cfg.vocab)

    fp = generate(cfg, params, prompts, Q.FP, gen_len=12)
    paper = generate(cfg, params, prompts, Q.PAPER, gen_len=12)
    bfp = generate(cfg, params, prompts,
                   Q.QuantConfig(linear="BFP4", nonlinear="BFP10"), gen_len=12)

    agree = lambda a, b: float(jnp.mean((a == b).astype(jnp.float32)))
    print("batched greedy decode, 4 prompts x 12 tokens")
    print(f"  fp       : {fp[0].tolist()}")
    print(f"  BBAL     : {paper[0].tolist()}   agreement {agree(fp, paper):.0%}")
    print(f"  BFP4/10  : {bfp[0].tolist()}   agreement {agree(fp, bfp):.0%}")
    print("(BBAL = BBFP(4,2) linears + BBFP(10,5) LUT nonlinear unit)")


if __name__ == "__main__":
    main()
