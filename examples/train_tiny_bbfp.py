"""End-to-end driver: train a ~100M-class LM for a few hundred steps with
BBFP QAT (fake-quant linears, straight-through gradients), with async
checkpointing and an injected mid-run failure to demonstrate restart.

Reduced width by default so it finishes on CPU; pass --full100m for the
real 100M config (slower).

  PYTHONPATH=src python examples/train_tiny_bbfp.py --steps 200
"""
import argparse

from repro.launch import train as T


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--quant", default="BBFP(4,2)")
    p.add_argument("--fail-at", type=int, default=120,
                   help="inject one failure to demo checkpoint-restart")
    args = p.parse_args()

    argv = ["--arch", "llama7b", "--tiny", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--quant", args.quant,
            "--ckpt-dir", "/tmp/repro_example_ckpt",
            "--ckpt-every", "50", "--log-every", "20"]
    if args.fail_at >= 0:
        argv += ["--fail-at", str(args.fail_at)]
    state, hist = T.main(argv)
    print(f"\ntrained with {args.quant} QAT: loss {hist['loss'][0]:.3f} -> "
          f"{hist['loss'][-1]:.3f}, survived {hist['restarts']} failure(s)")


if __name__ == "__main__":
    main()
