"""Run one quantised forward + one decode step for EVERY assigned
architecture (reduced configs) — the 10-arch zoo behind one API.

  PYTHONPATH=src python examples/multiarch_smoke.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M
from repro.quant import linear as Q


def main():
    key = jax.random.PRNGKey(0)
    for arch in configs.ARCHS:
        cfg = configs.smoke_config(arch)
        params = M.init(cfg, key)
        batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
        extras = {}
        if cfg.vis_len:
            extras["vis_embed"] = jax.random.normal(key, (2, cfg.vis_len, cfg.d_model)) * 0.1
            batch.update(extras)
        if cfg.family == "whisper":
            extras["frames"] = jax.random.normal(key, (2, cfg.encoder.n_frames, cfg.d_model)) * 0.1
            batch.update(extras)
        loss, _ = M.loss_fn(params, cfg, batch, Q.PAPER)
        _, cache = M.prefill(params, cfg, batch["tokens"], Q.PAPER,
                             max_len=24 + cfg.vis_len, **extras)
        logits, _ = M.decode_step(params, cfg, cache,
                                  batch["tokens"][:, :1], Q.PAPER)
        print(f"  {cfg.name:24s} [{cfg.family:8s}] loss={float(loss):5.2f} "
              f"decode_logits={tuple(logits.shape)}  BBAL-quantised OK")


if __name__ == "__main__":
    main()
