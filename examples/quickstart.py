"""Quickstart: the BBFP data format and the BBAL computation units in 60s.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import bbfp as B
from repro.core import error as E
from repro.core import nonlinear as NL
from repro.kernels import ops


def main():
    key = jax.random.PRNGKey(0)

    print("=== 1. BBFP vs BFP on an outlier-heavy tensor (Fig. 1a regime) ===")
    x = E.llm_activation_sample(key, (1024, 512))
    for fmt in [B.BFP4, B.BBFP31, B.BFP6, B.BBFP42, B.BBFP63]:
        print(f"  {fmt.name:10s} bits/elt={B.equivalent_bit_width(fmt):5.2f} "
              f"snr={float(E.snr_db(x, fmt)):5.1f} dB")

    print("\n=== 2. The shared-exponent insight (Eq. 9 / Fig. 3) ===")
    for name, off in [("max (plain BFP)", 2), ("max-1", 1),
                      ("max-(m-o)  <- paper", 0), ("max-3", -1)]:
        fmt = B.QuantFormat("bbfp", 4, 2, exponent_offset=off)
        print(f"  {name:20s} mse={float(E.empirical_mse(x, fmt)):.2e}")

    print("\n=== 3. BBFP matmul (the PE array, as a Pallas TPU kernel) ===")
    a = jax.random.normal(key, (256, 512))
    b = jax.random.normal(jax.random.fold_in(key, 1), (512, 256))
    c_fp = a @ b
    for fmt in ["BBFP(4,2)", "BBFP(6,3)"]:
        c_q = ops.bbfp_matmul(a, b, fmt)
        rel = float(jnp.linalg.norm(c_q - c_fp) / jnp.linalg.norm(c_fp))
        print(f"  {fmt}: relative GEMM error {rel:.4f} "
              f"(int8-MXU path: {B.folded_max(B.parse_format(fmt)) <= 127})")

    print("\n=== 4. The nonlinear unit: exponent-segmented LUT softmax ===")
    scores = jax.random.normal(key, (4, 2048)) * 2
    p_ref = jax.nn.softmax(scores, -1)
    p_bb = NL.softmax_lut(scores, fmt=B.BBFP105)
    p_bf = NL.softmax_lut(scores, fmt=B.BFP10)
    l1 = lambda p: float(jnp.mean(jnp.sum(jnp.abs(p - p_ref), -1)))
    print(f"  BBFP(10,5) LUT softmax L1: {l1(p_bb):.4f}")
    print(f"  BFP10      LUT softmax L1: {l1(p_bf):.4f}   <- block-max "
          f"alignment loses the near-zero logits (Table IV)")
    spec = NL.get_lut("exp", B.BBFP105)
    print(f"  table bank: {spec.table.nbytes // 1024} KiB, "
          f"{spec.n_subtables} active sub-tables, 7-bit addresses")


if __name__ == "__main__":
    main()
