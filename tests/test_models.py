"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (required deliverable (f)); plus decode
consistency vs teacher forcing."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as M
from repro.quant import linear as Q

B_, S_ = 2, 16
KEY = jax.random.PRNGKey(0)


def batch_for(cfg, b=B_, s=S_):
    out = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab),
           "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if cfg.vis_len:
        out["vis_embed"] = jax.random.normal(KEY, (b, cfg.vis_len, cfg.d_model)) * 0.1
    if cfg.family == "whisper":
        out["frames"] = jax.random.normal(KEY, (b, cfg.encoder.n_frames, cfg.d_model)) * 0.1
    return out


def extras_for(cfg, batch):
    return {k: v for k, v in batch.items() if k in ("vis_embed", "frames")}


@pytest.mark.parametrize("arch", configs.ARCHS)
@pytest.mark.parametrize("quant", ["fp", "paper"])
def test_smoke_forward_loss(arch, quant):
    cfg = configs.smoke_config(arch)
    params = M.init(cfg, KEY)
    batch = batch_for(cfg)
    qcfg = Q.PAPER if quant == "paper" else Q.FP
    loss, metrics = M.loss_fn(params, cfg, batch, qcfg)
    assert jnp.isfinite(loss), (arch, quant)
    assert float(loss) > 0
    mod = M.family_module(cfg)
    kwargs = extras_for(cfg, batch)
    if cfg.family == "whisper":
        logits, _, _ = mod.forward(params, cfg, batch["tokens"], qcfg, **kwargs)
        assert logits.shape == (B_, S_, cfg.vocab)
    elif cfg.vis_len:
        logits, _, _ = mod.forward(params, cfg, batch["tokens"], qcfg, **kwargs)
        assert logits.shape == (B_, S_ + cfg.vis_len, cfg.vocab)
    else:
        logits, _, _ = mod.forward(params, cfg, batch["tokens"], qcfg)
        assert logits.shape == (B_, S_, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step(arch):
    """one real optimiser step; params change; loss finite."""
    from repro.launch import steps as ST
    from repro.optim import adamw as O
    cfg = configs.smoke_config(arch)
    state = ST.make_init_state(cfg, O.AdamWConfig(lr=1e-3), KEY)
    step = ST.make_train_step(cfg, O.AdamWConfig(lr=1e-3), Q.FP, remat=False)
    before = jax.tree.leaves(state["params"])[0].copy()
    state, metrics = jax.jit(step)(state, batch_for(cfg))
    assert jnp.isfinite(metrics["loss"])
    after = jax.tree.leaves(state["params"])[0]
    assert float(jnp.max(jnp.abs(after - before))) > 0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = configs.smoke_config(arch)
    if cfg.moe:  # kill capacity-drop noise for the equivalence check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init(cfg, KEY)
    batch = batch_for(cfg)
    tokens = batch["tokens"]
    kwargs = extras_for(cfg, batch)
    mod = M.family_module(cfg)
    full_logits, _, _ = mod.forward(params, cfg, tokens, Q.FP, **kwargs)
    _, cache = M.prefill(params, cfg, tokens[:, :S_ - 1], Q.FP,
                         max_len=S_ + 4 + cfg.vis_len, **kwargs)
    logits_d, cache = M.decode_step(params, cfg, cache, tokens[:, S_ - 1:S_], Q.FP)
    ref = full_logits[:, -1]
    err = float(jnp.max(jnp.abs(logits_d - ref)))
    scale = max(float(jnp.max(jnp.abs(ref))), 1.0)
    assert err < 3e-2 * scale, (arch, err, scale)


def test_vlm_loss_ignores_vis_positions():
    cfg = configs.smoke_config("internvl2_76b")
    params = M.init(cfg, KEY)
    batch = batch_for(cfg)
    loss, _ = M.loss_fn(params, cfg, batch, Q.FP)
    assert jnp.isfinite(loss)


def test_gemma3_local_global_pattern():
    cfg = configs.full_config("gemma3-4b")
    flags = [cfg.layer_is_global(i) for i in range(cfg.n_layers)]
    assert sum(flags) == 5  # layers 5,11,17,23,29 (34 layers, every 6th)
    assert flags[5] and not flags[4]


@pytest.mark.parametrize("s,hd_v", [(4096, 32), (4352, 32), (4096, 16), (300, 24)])
def test_chunked_attention_matches_full(s, hd_v):
    """online-softmax chunked path == full path (fp, no quant), including
    non-divisible seq lengths (vlm) and v_dim != q_dim (MLA)."""
    from repro.models import attention as A
    b, kh, g, hd = 1, 2, 2, 32
    q = jax.random.normal(KEY, (b, s, kh, g, hd), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kh, hd), jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kh, hd_v), jnp.float32)
    pos = jnp.arange(s)
    scale = 1.0 / jnp.sqrt(hd)
    full = A._full_attention(q, k, v, pos, pos, True, None, scale, Q.FP)
    chunk = A._chunked_attention(q, k, v, pos, pos, True, None, scale, Q.FP)
    assert float(jnp.max(jnp.abs(full - chunk))) < 2e-5


def test_mla_cache_is_compressed():
    """MLA decode cache stores (lora + rope) per position, not heads*dim."""
    cfg = configs.smoke_config("deepseek_v2_lite_16b")
    cache = M.init_cache(cfg, 2, 32)
    leaves = {p: l for p, l in jax.tree_util.tree_flatten_with_path(cache["layers"])[0]}
    sizes = {str(k): v.shape for k, v in leaves.items()}
    assert any(v[-1] == cfg.mla.kv_lora_rank for v in sizes.values())
    assert all(v[-1] != cfg.n_heads * cfg.head_dim for v in sizes.values())
