"""End-to-end integration: real training runs, quantised and fault-injected."""
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow   # full suite on main; excluded from PR CI

from repro import configs
from repro.data import SyntheticLMDataset
from repro.launch import steps as ST
from repro.optim import adamw as O
from repro.quant import linear as Q
from repro.runtime import FailureInjector, resilient_train_loop


def _run(quant="none", nonlinear="none", steps=40, compress=False,
         ckpt_dir=None, fail_at=()):
    cfg = configs.get("llama7b").tiny_lm_config(vocab=128)
    qcfg = Q.QuantConfig(linear=quant, nonlinear=nonlinear)
    ocfg = O.AdamWConfig(lr=2e-3, total_steps=steps, warmup_steps=5)
    ds = SyntheticLMDataset(vocab=128, seq_len=64, seed=0)
    state = ST.make_init_state(cfg, ocfg, jax.random.PRNGKey(0),
                               compress_grads=compress)
    step_fn = jax.jit(ST.make_train_step(cfg, ocfg, qcfg, remat=False,
                                         compress_grads=compress))
    batch_fn = lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s, 8).items()}
    state, hist = resilient_train_loop(
        init_state=state, step_fn=step_fn, batch_fn=batch_fn, n_steps=steps,
        ckpt_dir=ckpt_dir or "/tmp/test_ckpt_none", ckpt_every=10,
        injector=FailureInjector(tuple(fail_at)))
    return hist


def test_fp_training_learns(tmp_path):
    hist = _run(ckpt_dir=str(tmp_path))
    first = sum(hist["loss"][:5]) / 5
    last = sum(hist["loss"][-5:]) / 5
    assert last < first - 0.3, (first, last)


def test_qat_bbfp_training_learns(tmp_path):
    """QAT with the paper's format: STE fake-quant still converges."""
    hist = _run(quant="BBFP(4,2)", ckpt_dir=str(tmp_path))
    assert hist["loss"][-1] < hist["loss"][0] - 0.3


def test_compressed_grads_training_learns(tmp_path):
    hist = _run(compress=True, ckpt_dir=str(tmp_path))
    assert hist["loss"][-1] < hist["loss"][0] - 0.3


def test_training_with_failures_matches_clean(tmp_path):
    clean = _run(steps=30, ckpt_dir=str(tmp_path / "a"))
    chaos = _run(steps=30, ckpt_dir=str(tmp_path / "b"), fail_at=(12, 23))
    assert chaos["restarts"] == 2
    assert abs(clean["loss"][-1] - chaos["loss"][-1]) < 1e-4
