"""Fused paged-attention Pallas kernel + sub-byte (packed4) BBFP KV.

Acceptance criteria of the fused-kernel PR:
  * kernel-level parity (Pallas interpret mode on CPU — this IS the CI
    validation): `kernels.paged_attention` matches the gathered-dequant jnp
    reference for q_len=1 decode AND q_len=chunk causal prefill, with
    sentinel-padded tables, page-boundary rows, windows, and both packed
    (int8 codes) and packed4 (two nibble codes per byte) pools;
  * engine-level parity: fused vs unfused GQA serving is greedy-token-
    IDENTICAL through ContinuousBatcher at fp32 compute (exact token
    parity is only well-posed at fp32 — the online softmax and the
    unfused full-row softmax differ in ulps, and bf16 rounding can
    amplify an ulp into a different argmax);
  * MLA accepts paged_attn="fused" and IGNORES it (absorbed-form decode
    cannot route through the GQA kernel) — fused==unfused exactly; the
    packed-MLA-vs-fp-MLA CLOSE-tolerance caveat is the pre-existing
    latent-quantisation tradeoff (attention.mla_apply), not a kernel gap;
  * packed4 nibble pools: value-exact pack/unpack round-trip, bit-exact
    snapshot/restore (int8 page bytes move verbatim), and the storage
    guard matrix (nibble-codable formats only, GQA only, fused only).
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import bbfp as B
from repro.kernels import paged_attention as PA
from repro.models import attention as A
from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime import paged_kv as PK
from repro.runtime.batcher import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(5)


def _fp32(arch="llama7b"):
    return dataclasses.replace(configs.smoke_config(arch),
                               compute_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# nibble packing (bbfp.pack_kv_nibble / unpack_kv_nibble)
# ---------------------------------------------------------------------------

def test_kv_packable4():
    # bidirectional codes need 2+m bits -> widest 4-bit member is BBFP(2,1);
    # unidirectional BFP fits m<=3
    assert B.kv_packable4(B.parse_format("BBFP(2,1)"))
    assert not B.kv_packable4(B.parse_format("BBFP(3,1)"))
    assert not B.kv_packable4(B.parse_format("BBFP(6,3)"))
    assert B.kv_packable4(B.parse_format("BFP3"))
    assert not B.kv_packable4(B.parse_format("BFP4"))


@pytest.mark.parametrize("fmt_name", ["BBFP(2,1)", "BFP3"])
def test_nibble_roundtrip_matches_fake_quant(fmt_name):
    fmt = B.parse_format(fmt_name)
    x = jax.random.normal(KEY, (3, 7, 64), jnp.float32) * 2.0
    enc = B.pack_kv_nibble(x, fmt)
    assert enc["q"].shape == (3, 7, 32) and enc["q"].dtype == jnp.int8
    dec = B.unpack_kv_nibble(enc, fmt, out_dtype=jnp.float32)
    ref = B.fake_quant(x, fmt, axis=-1)
    assert (np.asarray(dec) == np.asarray(ref)).all()
    # VALUES are stable under re-encode (codes need not be byte-canonical:
    # the two mantissa windows overlap, so flag=1/mant=1 == flag=0/mant=2)
    dec2 = B.unpack_kv_nibble(B.pack_kv_nibble(dec, fmt), fmt,
                              out_dtype=jnp.float32)
    assert (np.asarray(dec2) == np.asarray(dec)).all()


def test_nibble_small_head_dim():
    fmt = B.parse_format("BBFP(2,1)")
    x = jax.random.normal(KEY, (2, 5, 16), jnp.float32)   # hd < block: pads
    dec = B.unpack_kv_nibble(B.pack_kv_nibble(x, fmt), fmt, jnp.float32)
    assert (np.asarray(dec) == np.asarray(B.fake_quant(x, fmt, axis=-1))).all()


# ---------------------------------------------------------------------------
# kernel-level parity (Pallas interpret mode) vs the jnp fallback
# ---------------------------------------------------------------------------

def _build_pools(kh, hd, n_pages, page, bt, pos, fmt, nibble, n_rows=65):
    """Scatter n_rows random KV rows through the block table (same append
    path serving uses), returning ({k,v} pools, the raw rows)."""
    bsz = bt.shape[0]
    hdq = hd // 2 if nibble else hd
    nb = -(-hd // B.DEFAULT_BLOCK)
    pool = lambda: {"q": jnp.zeros((n_pages, page, kh, hdq), jnp.int8),
                    "exp": jnp.zeros((n_pages, page, kh, nb), jnp.int8)}
    k_pool, v_pool = pool(), pool()
    rows = jax.random.normal(jax.random.fold_in(KEY, 9),
                             (bsz, n_rows, kh, hd), jnp.float32)
    for t in range(n_rows):
        at = jnp.minimum(jnp.full((bsz,), t, jnp.int32), pos)
        k_pool = A._paged_append(k_pool, bt, at, rows[:, t:t + 1], fmt)
        v_pool = A._paged_append(v_pool, bt, at, rows[:, t:t + 1] * 0.5, fmt)
    return k_pool, v_pool


def _ref_attention(q_grp, k_pool, v_pool, bt, pos, window, fmt, nibble):
    """The unfused decode branch, verbatim: gather+dequant view, pos/window
    mask, full-row fp32 softmax."""
    b, s, kh, g, hd = q_grp.shape
    k = A._paged_view(k_pool, bt, fmt, jnp.float32, nibble=nibble)
    v = A._paged_view(v_pool, bt, fmt, jnp.float32, nibble=nibble)
    kp = jnp.arange(k.shape[1])
    qp = pos[:, None] + jnp.arange(s)
    valid = (kp[None, None, :] <= qp[..., None]) & \
            (kp[None, None, :] > qp[..., None] - window)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q_grp, k
                        ).astype(jnp.float32) * scale
    probs = Q.qsoftmax(scores, Q.FP, axis=-1, where=valid[:, None, None])
    return jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(jnp.float32), v)


@pytest.mark.parametrize("s,window,fmt_name,nibble", [
    (1, None, "BBFP(6,3)", False),       # decode
    (4, None, "BBFP(6,3)", False),       # chunked prefill (causal in-chunk)
    (1, 40, "BBFP(6,3)", False),         # sliding window
    (1, None, "BBFP(2,1)", True),        # packed4 decode
    (4, 17, "BBFP(2,1)", True),          # packed4 windowed prefill
])
def test_kernel_matches_jnp_fallback(s, window, fmt_name, nibble):
    """Page-boundary rows (pos 31->32), a partially-written last page
    (pos 37 in a 2-page span), and a sentinel-padded table (slot 1's tail,
    slot 2's last entry) are all in-distribution here."""
    fmt = B.parse_format(fmt_name)
    kh, hd, page, n_pages = 4, 64, 32, 16
    bt = jnp.asarray([[0, 1, 2, 3], [4, 5, 16, 16], [6, 7, 8, 16]], jnp.int32)
    pos = jnp.asarray([37, 31, 60], jnp.int32)
    k_pool, v_pool = _build_pools(kh, hd, n_pages, page, bt, pos, fmt, nibble)
    q = jax.random.normal(jax.random.fold_in(KEY, 3),
                          (3, s, kh, 1, hd), jnp.float32)
    eff = window if window is not None else bt.shape[1] * page + 1
    out = PA.paged_attention(q, k_pool, v_pool, bt, pos,
                             jnp.asarray(eff, jnp.int32),
                             fmt=fmt, nibble=nibble)
    ref = _ref_attention(q, k_pool, v_pool, bt, pos, eff, fmt, nibble)
    assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 2e-6


def test_kernel_lut_exp_close():
    """With a nonlinear format the in-kernel exp comes from the segmented
    LUT; online rescale makes it close-to (not bitwise) the full-row LUT
    softmax — same tolerance class as the chunked-prefill path."""
    fmt = B.parse_format("BBFP(6,3)")
    kh, hd, page, n_pages = 4, 64, 32, 8
    bt = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    pos = jnp.asarray([50], jnp.int32)
    k_pool, v_pool = _build_pools(kh, hd, n_pages, page, bt, pos, fmt, False)
    q = jax.random.normal(KEY, (1, 1, kh, 1, hd), jnp.float32)
    out = PA.paged_attention(q, k_pool, v_pool, bt, pos,
                             jnp.asarray(129, jnp.int32), fmt=fmt,
                             exp_fmt=B.parse_format("BBFP(10,5)"))
    ref = _ref_attention(q, k_pool, v_pool, bt, pos, 129, fmt, False)
    assert np.isfinite(np.asarray(out)).all()
    scale = max(np.abs(np.asarray(ref)).max(), 0.05)
    # LUT address quantisation + online rescale vs exact fp32 softmax:
    # a few percent, same class as the flash_lut_attention oracle bound
    assert np.abs(np.asarray(out) - np.asarray(ref)).max() / scale < 0.05


# ---------------------------------------------------------------------------
# engine-level parity: fused vs unfused through ContinuousBatcher
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, qcfg, prompts, gen, **kw):
    bat = ContinuousBatcher(cfg, params, qcfg, n_slots=4, max_len=96,
                            n_pages=40, **kw)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    fin, _ = bat.run()
    return {r.rid: r.out_tokens for r in fin}


def test_fused_tokens_match_unfused_gqa():
    """THE acceptance criterion: greedy-token-identical fused vs unfused
    for packed GQA KV, decode AND chunked prefill (prefill_chunk=8 makes
    the 30-token prompt take 4 chunk steps), with page-boundary crossings
    (len 30 + 6 generated crosses row 32) and an idle sentinel slot
    (3 requests in 4 slots)."""
    cfg = _fp32()
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    lens = [5, 9, 30]
    prompts = [jax.random.randint(jax.random.fold_in(KEY, i), (n,), 0,
                                  cfg.vocab) for i, n in enumerate(lens)]
    out_u = _run_engine(cfg, params, qcfg, prompts, 6,
                        kv_storage="packed", paged_attn="unfused",
                        prefill_chunk=8)
    out_f = _run_engine(cfg, params, qcfg, prompts, 6,
                        kv_storage="packed", paged_attn="fused",
                        prefill_chunk=8)
    assert out_f == out_u, (out_f, out_u)


def test_packed4_fused_serving_runs():
    """packed4 end to end: the engine serves nibble pools through the fused
    kernel and is deterministic run-to-run. (No unfused twin exists by
    design — the batcher rejects packed4+unfused — so cross-path token
    parity for packed4 lives at the kernel level above.)"""
    cfg = _fp32()
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(2,1)")
    prompts = [jax.random.randint(jax.random.fold_in(KEY, 40 + i), (n,), 0,
                                  cfg.vocab) for i, n in enumerate([7, 33])]
    kw = dict(kv_storage="packed4", paged_attn="fused")
    a = _run_engine(cfg, params, qcfg, prompts, 5, **kw)
    b = _run_engine(cfg, params, qcfg, prompts, 5, **kw)
    assert a == b and all(len(t) == 5 for t in a.values())


def test_mla_fused_flag_ignored():
    """MLA accepts paged_attn='fused' and keeps the jnp fallback (absorbed
    decode can't route through the GQA kernel) — tokens EXACTLY match the
    unfused run. The close-tolerance caveat for MLA is packed-vs-fp latent
    quantisation (attention.mla_apply's documented tradeoff), orthogonal
    to the fused flag."""
    cfg = _fp32("deepseek_v2_lite_16b")
    assert cfg.mla is not None
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    prompts = [jax.random.randint(jax.random.fold_in(KEY, 60 + i), (n,), 0,
                                  cfg.vocab) for i, n in enumerate([6, 20])]
    out_u = _run_engine(cfg, params, qcfg, prompts, 4, kv_storage="packed",
                        paged_attn="unfused")
    out_f = _run_engine(cfg, params, qcfg, prompts, 4, kv_storage="packed",
                        paged_attn="fused")
    assert out_f == out_u


# ---------------------------------------------------------------------------
# packed4 snapshot/restore + storage guards
# ---------------------------------------------------------------------------

def test_packed4_snapshot_restore_bit_exact():
    """Warm restart over nibble pools: snapshot a served packed4 engine's
    radix pages, restore into a fresh engine, and re-serve the same
    prompts. First-round prefix hits prove the pages were ADOPTED, and
    identical greedy tokens at fp32 prove the int8 nibble bytes moved
    bit-exactly (any flipped code would shift a dequantised K/V row and
    the argmax with it)."""
    cfg = _fp32()
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(2,1)")
    prefix = jax.random.randint(jax.random.fold_in(KEY, 80), (64,), 0,
                                cfg.vocab)
    prompts = [jnp.concatenate([prefix, jax.random.randint(
        jax.random.fold_in(KEY, 81 + i), (n,), 0, cfg.vocab)])
        for i, n in enumerate([5, 9])]
    kw = dict(kv_storage="packed4", paged_attn="fused", max_len=128)

    donor = ContinuousBatcher(cfg, params, qcfg, n_slots=4, n_pages=40, **kw)
    for i, p in enumerate(prompts):
        donor.submit(Request(rid=i, prompt=p, max_new=4))
    donor.run()
    ref = {r.rid: r.out_tokens for r in donor.finished}
    snap = tempfile.mkdtemp()
    n_snap = donor.snapshot_kv(snap)
    assert n_snap > 0

    warm = ContinuousBatcher(cfg, params, qcfg, n_slots=4, n_pages=40, **kw)
    assert warm.restore_kv(snap) == n_snap
    for i, p in enumerate(prompts):
        warm.submit(Request(rid=i, prompt=p, max_new=4))
    warm.run()
    assert {r.rid: r.out_tokens for r in warm.finished} == ref
    assert warm.prefix_hit_pages > 0       # restored pages actually served


def test_packed4_storage_guards():
    cfg = configs.smoke_config("llama7b")
    # page layout: only nibble-codable formats may pack two codes per byte
    with pytest.raises(ValueError, match="nibble"):
        PK.init_paged_cache(cfg, 2, 64, n_pages=4, storage="packed4",
                            kv_fmt=B.parse_format("BBFP(6,3)"))
    mla_cfg = configs.smoke_config("deepseek_v2_lite_16b")
    with pytest.raises(ValueError, match="GQA"):
        PK.init_paged_cache(mla_cfg, 2, 64, n_pages=4, storage="packed4",
                            kv_fmt=B.parse_format("BBFP(2,1)"))
    # engine guard matrix
    params = M.init(cfg, KEY)
    q21 = Q.QuantConfig(kv_cache="BBFP(2,1)")
    with pytest.raises(ValueError, match="paged_attn='fused'"):
        ContinuousBatcher(cfg, params, q21, kv_storage="packed4",
                          paged_attn="unfused")
    with pytest.raises(ValueError, match="nothing to fuse"):
        ContinuousBatcher(cfg, params, q21, kv_storage="fp",
                          paged_attn="fused")
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatcher(cfg, params, q21, kv_layout="dense",
                          kv_storage="packed4", paged_attn="fused")


def test_fused_serves_on_tensor_parallel_mesh():
    """Fused + mesh now COMPOSES (flash-decoding page-dim sharding): a
    tp=1 serving mesh routes the fused path through the shard_map wrapper
    — per-shard kernel partials + the log-sum-exp merge, which at one
    shard is bitwise the kernel's own normalisation — so the meshed engine
    must be greedy-token-identical to the no-mesh fused engine even on a
    single device. kv_stats reports the page-dim sharding mode."""
    from repro.launch.mesh import make_serving_mesh
    cfg = _fp32()
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    prompts = [jax.random.randint(jax.random.fold_in(KEY, 90 + i), (n,), 0,
                                  cfg.vocab) for i, n in enumerate([5, 30])]
    kw = dict(kv_storage="packed", paged_attn="fused", prefill_chunk=8)
    ref = _run_engine(cfg, params, qcfg, prompts, 6, **kw)

    mesh = make_serving_mesh(tp=1)
    bat = ContinuousBatcher(cfg, params, qcfg, n_slots=4, max_len=96,
                            n_pages=40, mesh=mesh, **kw)
    stats = bat.kv_stats()
    assert stats["paged_attn"] == stats["paged_attn_effective"] == "fused"
    assert stats["kv_shard_axis"] == "pages"
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=6))
    fin, _ = bat.run()
    assert {r.rid: r.out_tokens for r in fin} == ref
