"""Data pipeline, optimizer, gradient compression, checkpointing, runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import SyntheticLMDataset
from repro.optim import adamw as O
from repro.optim import compression as GC
from repro.runtime import FailureInjector, StragglerMonitor, resilient_train_loop

pytestmark = pytest.mark.slow   # full suite on main; excluded from PR CI


# ---------------- data ----------------

def test_data_deterministic_resume():
    ds = SyntheticLMDataset(vocab=128, seq_len=32, seed=7)
    a = ds.batch(5, 8)
    b = ds.batch(5, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(6, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_shifted():
    ds = SyntheticLMDataset(vocab=128, seq_len=16, seed=0)
    b = ds.batch(0, 4)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)


def test_data_host_sharding_partitions():
    ds = SyntheticLMDataset(vocab=128, seq_len=8, seed=0)
    full = [ds.batch(3, 8, process_index=i, process_count=4)["tokens"]
            for i in range(4)]
    assert all(f.shape == (2, 8) for f in full)
    # processes generate distinct slices (different rng streams)
    assert not np.array_equal(full[0], full[1])


def test_data_learnable_structure():
    """bigram structure: successor entropy << unigram entropy."""
    ds = SyntheticLMDataset(vocab=64, seq_len=256, seed=1)
    toks = ds.batch(0, 16)["tokens"]
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    frac_top4 = np.mean([
        np.mean([v in set(np.bincount(vs, minlength=64).argsort()[-4:]) for v in vs])
        for vs in pairs.values() if len(vs) > 10])
    assert frac_top4 > 0.6  # most transitions covered by 4 successors


# ---------------- optimizer ----------------

def test_adamw_converges_quadratic():
    cfg = O.AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=100, warmup_steps=1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = O.adamw_init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = O.adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_clip():
    g, norm = O.clip_by_global_norm({"a": jnp.ones(100) * 10}, 1.0)
    assert abs(float(jnp.sqrt(jnp.sum(g["a"] ** 2))) - 1.0) < 1e-5
    assert float(norm) > 99


def test_compression_error_feedback_unbiased():
    """EF-compressed grads converge a least-squares problem ~ as well."""
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (64, 16))
    b = jax.random.normal(jax.random.fold_in(key, 1), (64,))
    def grad(w):
        return {"w": A.T @ (A @ w["w"] - b) / 64}
    def solve(compress):
        w = {"w": jnp.zeros(16)}
        err = GC.compression_init(w)
        for _ in range(300):
            g = grad(w)
            if compress:
                g, err = GC.compress_gradients(g, err)
            w = jax.tree.map(lambda p, gg: p - 0.1 * gg, w, g)
        return float(jnp.mean((A @ w["w"] - b) ** 2))
    plain, comp = solve(False), solve(True)
    assert comp < plain * 1.1 + 1e-3, (plain, comp)


# ---------------- checkpoint ----------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "step": jnp.asarray(7)}
    save_checkpoint(str(tmp_path), 42, tree)
    assert latest_step(str(tmp_path)) == 42
    step, restored = restore_checkpoint(str(tmp_path), tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(4)})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, {"x": jnp.full(4, s)})
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    _, t = restore_checkpoint(str(tmp_path), {"x": jnp.zeros(4)})
    assert float(t["x"][0]) == 4


def test_checkpoint_elastic_restore_different_sharding(tmp_path):
    """mesh-agnostic restore: re-lay arrays with a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import _make_mesh   # AxisType-compat mesh ctor
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 0, tree)
    mesh = _make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    _, restored = restore_checkpoint(str(tmp_path), tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


# ---------------- runtime ----------------

def _toy_setup(tmp_path):
    def step_fn(state, batch):
        new = {"w": state["w"] - 0.1 * batch["g"]}
        return new, {"loss": jnp.sum(new["w"] ** 2)}
    def batch_fn(step):
        return {"g": jnp.full((4,), float(step % 3 - 1))}
    return step_fn, batch_fn


def test_resilient_loop_recovers_from_failures(tmp_path):
    step_fn, batch_fn = _toy_setup(tmp_path)
    init = {"w": jnp.ones(4)}
    # failure-free reference
    ref, _ = resilient_train_loop(
        init_state=init, step_fn=step_fn, batch_fn=batch_fn, n_steps=20,
        ckpt_dir=str(tmp_path / "ref"), ckpt_every=5)
    # with two injected failures
    got, hist = resilient_train_loop(
        init_state=init, step_fn=step_fn, batch_fn=batch_fn, n_steps=20,
        ckpt_dir=str(tmp_path / "chaos"), ckpt_every=5,
        injector=FailureInjector((7, 13)))
    assert hist["restarts"] == 2
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(ref["w"]),
                               rtol=1e-6)


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(warmup=3, k_sigma=3.0)
    import random
    random.seed(0)
    for i in range(20):
        mon.observe(i, 0.1 + random.random() * 0.005)
    flagged = mon.observe(20, 1.5)
    assert flagged and mon.flagged
