"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

MATMUL_SHAPES = [(128, 128, 128), (256, 384, 128), (128, 256, 256),
                 (130, 100, 140), (64, 32, 16)]
FMTS = ["BBFP(4,2)", "BBFP(3,1)", "BBFP(6,3)", "BFP4", "BFP6", "INT8"]


@pytest.mark.parametrize("shape", MATMUL_SHAPES)
@pytest.mark.parametrize("fmt", FMTS)
def test_bbfp_matmul_vs_ref(shape, fmt):
    m, k, n = shape
    a = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32) * 2
    b = jax.random.normal(jax.random.PRNGKey(2), (k, n), jnp.float32)
    got = ops.bbfp_matmul(a, b, fmt)
    want = ref.bbfp_matmul_ref(a, b, fmt)
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale,
                               atol=2e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bbfp_matmul_dtypes(dtype):
    a = (jax.random.normal(jax.random.PRNGKey(1), (128, 128)) * 2).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(2), (128, 128)).astype(dtype)
    got = ops.bbfp_matmul(a, b, "BBFP(4,2)")
    want = ref.bbfp_matmul_ref(a, b, "BBFP(4,2)")
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale,
                               atol=2e-6)


def test_bbfp_matmul_batched_lead_dims():
    a = jax.random.normal(jax.random.PRNGKey(3), (4, 33, 96))
    b = jax.random.normal(jax.random.PRNGKey(4), (96, 40))
    got = ops.bbfp_matmul(a, b, "BBFP(4,2)")
    assert got.shape == (4, 33, 40)
    want = ref.bbfp_matmul_ref(a.reshape(-1, 96), b, "BBFP(4,2)").reshape(4, 33, 40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


LUT_SHAPES = [(8, 512), (16, 33, 700), (5000,), (3, 3, 3)]
LUT_FNS = ["exp", "one_plus_exp_neg", "sigmoid", "gelu_inner"]


@pytest.mark.parametrize("shape", LUT_SHAPES)
@pytest.mark.parametrize("fn", LUT_FNS)
def test_lut_kernel_vs_ref(shape, fn):
    x = jax.random.normal(jax.random.PRNGKey(5), shape) * 3
    got = ops.lut_apply(x, fn)
    want = ref.lut_apply_ref(x, fn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("fmt", ["BBFP(10,5)", "BFP10"])
def test_lut_kernel_formats(fmt):
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 512)) * 5
    got = ops.lut_apply(x, "exp", fmt)
    want = ref.lut_apply_ref(x, "exp", fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_lut_inside_jit():
    """regression: LUT table construction under an ambient jit trace."""
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 512))

    @jax.jit
    def f(x):
        return ops.lut_apply(x, "sigmoid")

    np.testing.assert_allclose(np.asarray(f(x)),
                               np.asarray(ref.lut_apply_ref(x, "sigmoid")),
                               rtol=0, atol=0)


def test_kernel_accuracy_vs_true_values():
    """the quantised matmul approximates the fp matmul within format error."""
    a = jax.random.normal(jax.random.PRNGKey(8), (256, 256))
    b = jax.random.normal(jax.random.PRNGKey(9), (256, 128))
    true = a @ b
    for fmt, tol in [("BBFP(6,3)", 0.02), ("BBFP(4,2)", 0.08), ("BFP4", 0.25)]:
        got = ops.bbfp_matmul(a, b, fmt)
        rel = float(jnp.linalg.norm(got - true) / jnp.linalg.norm(true))
        assert rel < tol, (fmt, rel)
