"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bbfp as B
from repro.kernels import ops, ref

MATMUL_SHAPES = [(128, 128, 128), (256, 384, 128), (128, 256, 256),
                 (130, 100, 140), (64, 32, 16)]
FMTS = ["BBFP(4,2)", "BBFP(3,1)", "BBFP(6,3)", "BFP4", "BFP6", "INT8"]
# every registered quantised format (the packed kernel must serve them all)
ALL_FMTS = [f.name for f in B.FORMATS.values() if f.kind != "none"]


@pytest.mark.parametrize("shape", MATMUL_SHAPES)
@pytest.mark.parametrize("fmt", FMTS)
def test_bbfp_matmul_vs_ref(shape, fmt):
    m, k, n = shape
    a = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32) * 2
    b = jax.random.normal(jax.random.PRNGKey(2), (k, n), jnp.float32)
    got = ops.bbfp_matmul(a, b, fmt)
    want = ref.bbfp_matmul_ref(a, b, fmt)
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale,
                               atol=2e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bbfp_matmul_dtypes(dtype):
    a = (jax.random.normal(jax.random.PRNGKey(1), (128, 128)) * 2).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(2), (128, 128)).astype(dtype)
    got = ops.bbfp_matmul(a, b, "BBFP(4,2)")
    want = ref.bbfp_matmul_ref(a, b, "BBFP(4,2)")
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale,
                               atol=2e-6)


def test_bbfp_matmul_batched_lead_dims():
    a = jax.random.normal(jax.random.PRNGKey(3), (4, 33, 96))
    b = jax.random.normal(jax.random.PRNGKey(4), (96, 40))
    got = ops.bbfp_matmul(a, b, "BBFP(4,2)")
    assert got.shape == (4, 33, 40)
    want = ref.bbfp_matmul_ref(a.reshape(-1, 96), b, "BBFP(4,2)").reshape(4, 33, 40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# packed-operand kernel (weight-stationary serving path)
# ---------------------------------------------------------------------------

PACKED_SHAPES = [(128, 128, 128), (130, 96, 140), (8, 256, 128), (4, 64, 256)]


@pytest.mark.parametrize("shape", PACKED_SHAPES)
@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_bbfp_matmul_packed_vs_fp_kernel(shape, fmt):
    """The packed kernel (weight pre-decomposed by pack_weight, consumed as
    stored) against the fp kernel (weight quantised in VMEM per call):
    pack_weight uses the identical quantiser and the kernels accumulate in
    the identical block order, so power-of-two-scale formats (bbfp/bfp) are
    BIT-EXACT. The int baseline's absmax scale is not a power of two, so its
    last bit depends on how the compiler fuses the scale multiplies (FMA) —
    there equality holds to fp32 roundoff. Covers both sides of the
    folded_max <= 127 int8-path boundary (INT8 sits exactly ON it at 127;
    BBFP(6,3) folds to 504 -> int16 storage, fp32 dot)."""
    m, k, n = shape
    f = B.parse_format(fmt)
    a = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32) * 2
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n), jnp.float32)
    packed = B.pack_weight(w, f, cast_dtype=None)
    want_dtype = jnp.int8 if B.folded_max(f) <= 127 else jnp.int16
    assert packed["q"].dtype == want_dtype, fmt
    got = ops.bbfp_matmul_packed(a, packed, fmt)
    fp_kernel = ops.bbfp_matmul(a, w, fmt)
    if f.kind == "int":
        scale = float(jnp.max(jnp.abs(fp_kernel))) + 1e-9
        np.testing.assert_allclose(np.asarray(got) / scale,
                                   np.asarray(fp_kernel) / scale, atol=2e-6)
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(fp_kernel))
    # and against the fake-quant oracle, like the fp kernel's own test
    want = ref.bbfp_matmul_ref(a, w, fmt)
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=2e-6)


def test_bbfp_matmul_packed_batched_lead_dims():
    a = jax.random.normal(jax.random.PRNGKey(3), (4, 33, 96))
    w = jax.random.normal(jax.random.PRNGKey(4), (96, 40))
    packed = B.pack_weight(w, B.BBFP42, cast_dtype=None)
    got = ops.bbfp_matmul_packed(a, packed, "BBFP(4,2)")
    assert got.shape == (4, 33, 40)
    want = ref.bbfp_matmul_ref(a.reshape(-1, 96), w, "BBFP(4,2)").reshape(4, 33, 40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_packed_dtype_mismatch_rejected():
    """an int16-folded weight (BBFP(6,3)) must never reach an int8-path
    fmt_name's MXU cast — the wrapper rejects the inconsistent pairing."""
    a = jax.random.normal(jax.random.PRNGKey(5), (16, 64))
    packed = B.pack_weight(jax.random.normal(jax.random.PRNGKey(6), (64, 128)),
                           B.BBFP63, cast_dtype=None)
    with pytest.raises(AssertionError, match="int8-path"):
        ops.bbfp_matmul_packed(a, packed, "BBFP(4,2)")


def test_row_thin_dispatch_hits_kernel(monkeypatch):
    """decode-shaped GEMMs (rows = batch size) must run the Pallas kernel
    with the tm=8 row tile, not fall back to the jnp reference — and truly
    tiny problems must still fall back. Verifies the pad/slice logic for
    row counts that are not multiples of the tile."""
    calls = {"fp": 0, "packed": 0}
    real_fp, real_pk = ops._matmul_kernel_call, ops._matmul_packed_call
    monkeypatch.setattr(ops, "_matmul_kernel_call",
                        lambda *a, **k: (calls.__setitem__("fp", calls["fp"] + 1),
                                         real_fp(*a, **k))[1])
    monkeypatch.setattr(ops, "_matmul_packed_call",
                        lambda *a, **k: (calls.__setitem__("packed", calls["packed"] + 1),
                                         real_pk(*a, **k))[1])
    w = jax.random.normal(jax.random.PRNGKey(7), (96, 256))
    packed = B.pack_weight(w, B.BBFP42, cast_dtype=None)
    for rows in (4, 5, 8):            # 4/5 pad to 8; 5 exercises the slice
        a = jax.random.normal(jax.random.PRNGKey(rows), (rows, 96)) * 2
        got_fp = ops.bbfp_matmul(a, w, "BBFP(4,2)")
        got_pk = ops.bbfp_matmul_packed(a, packed, "BBFP(4,2)")
        want = ref.bbfp_matmul_ref(a, w, "BBFP(4,2)")
        np.testing.assert_allclose(np.asarray(got_fp), np.asarray(want), atol=1e-4)
        np.testing.assert_allclose(np.asarray(got_pk), np.asarray(want), atol=1e-4)
    assert calls == {"fp": 3, "packed": 3}      # every call hit the kernel
    # below the dispatch floor (rows * n < 8*128): jnp reference, no kernel
    a = jax.random.normal(jax.random.PRNGKey(9), (4, 96))
    small_w = w[:, :16]
    ops.bbfp_matmul(a, small_w, "BBFP(4,2)")
    ops.bbfp_matmul_packed(
        a, {"q": packed["q"][:, :16], "scale": packed["scale"][:, :16]},
        "BBFP(4,2)")
    assert calls == {"fp": 3, "packed": 3}      # unchanged: fell back to ref


def test_qlinear_packed_routes_both_ways():
    """the qlinear dispatch bug: packed {"q","scale"} params must respect
    qcfg.use_kernel — kernel path -> bbfp_matmul_packed, no-kernel path ->
    the fused-dequant fp dot. Both agree with the fake-quant baseline."""
    from repro.quant import linear as Q
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 16, 64), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(11), (64, 96), jnp.bfloat16)
    params_packed = {**B.pack_weight(w, B.BBFP42),
                     "b": jnp.ones((96,), jnp.bfloat16)}
    y_fake = Q.qlinear({"w": w, "b": params_packed["b"]}, x,
                       Q.QuantConfig(linear="BBFP(4,2)"))
    y_nok = Q.qlinear(params_packed, x, Q.QuantConfig(linear="BBFP(4,2)"))
    y_ker = Q.qlinear(params_packed, x,
                      Q.QuantConfig(linear="BBFP(4,2)", use_kernel=True))
    for name, y in (("no-kernel", y_nok), ("kernel", y_ker)):
        err = float(jnp.max(jnp.abs((y - y_fake).astype(jnp.float32))))
        ref_mag = float(jnp.max(jnp.abs(y_fake.astype(jnp.float32)))) + 1e-9
        assert err <= 1e-2 * ref_mag, (name, err)


def test_packed_params_generate_gqa_and_mla():
    """pack_params'd projections thread through the model layers end-to-end:
    GQA decodes with packed weights on BOTH qlinear paths (kernel and
    fused-dequant), and MLA's absorbed decode reads packed w_uk/w_uv through
    weight_view instead of crashing on the missing "w" leaf.

    GQA no-kernel packed == fake-quant token-for-token (unpack ==
    fake_quant exactly). MLA is agreement-only: its absorbed decode uses
    w_uk/w_uv RAW in the fp run (prefill quantises them, decode does not),
    while packed weights are on-grid in both phases — so packed-MLA is the
    self-consistent one and can't match the fp run bitwise. The kernel run
    may also flip near-tied logits (different fp32 accumulation order)."""
    from repro import configs
    from repro.launch.serve import generate
    from repro.models import model as M
    from repro.quant import linear as Q
    from repro.quant.packed import pack_params

    def unpack_tree(orig, node):
        """fp twin of the packed params (every weight exactly on the format
        grid), mirroring the original structure: a {"w"} dict stays a dict,
        a bare packed leaf (MoE expert weights) unpacks back to an array."""
        if isinstance(node, dict) and "q" in node and "scale" in node:
            w = B.unpack_weight(node)
            if isinstance(orig, dict):
                return {"w": w, **{k: v for k, v in node.items()
                                   if k not in ("q", "scale")}}
            return w
        if isinstance(node, dict):
            return {k: unpack_tree(orig[k], v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(unpack_tree(o, v) for o, v in zip(orig, node))
        return node

    for arch in ("llama7b", "deepseek_v2_lite_16b"):
        cfg = configs.smoke_config(arch)
        params = M.init(cfg, jax.random.PRNGKey(0))
        fmt = B.BBFP42
        packed = pack_params(params, fmt)
        assert any("q" in str(jax.tree_util.keystr(kp))
                   for kp, _ in jax.tree_util.tree_leaves_with_path(packed))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
        qcfg = Q.QuantConfig(linear="BBFP(4,2)")
        # strong invariant, both archs: serving packed storage ==
        # serving the dequantised weights, token-for-token (requantisation
        # of on-grid weights is idempotent)
        t_grid = generate(cfg, unpack_tree(params, packed), prompts, qcfg,
                          gen_len=5)
        t_packed = generate(cfg, packed, prompts, qcfg, gen_len=5)
        np.testing.assert_array_equal(np.asarray(t_packed),
                                      np.asarray(t_grid), err_msg=arch)
        # GQA only: the fp-params run quantises every weight it uses, so
        # packed == fake-quant exactly. (MLA's absorbed decode uses w_uk/
        # w_uv RAW on fp params while prefill quantises them — the packed
        # run is the self-consistent one and can't match the fp run.)
        if arch == "llama7b":
            t_fake = generate(cfg, params, prompts, qcfg, gen_len=5)
            np.testing.assert_array_equal(np.asarray(t_packed),
                                          np.asarray(t_fake), err_msg=arch)
        # kernel path on packed params: same quantised operands, different
        # fp32 accumulation order — compare prefill logits, not greedy
        # token chains (near-tied random-init logits make chains diverge)
        lg_nok, _ = M.prefill(packed, cfg, prompts, qcfg, max_len=16)
        lg_ker, _ = M.prefill(
            packed, cfg, prompts,
            Q.QuantConfig(linear="BBFP(4,2)", use_kernel=True), max_len=16)
        scale = float(jnp.max(jnp.abs(lg_nok.astype(jnp.float32)))) + 1e-9
        err = float(jnp.max(jnp.abs((lg_ker - lg_nok).astype(jnp.float32))))
        assert err <= 0.05 * scale, (arch, err, scale)


LUT_SHAPES = [(8, 512), (16, 33, 700), (5000,), (3, 3, 3)]
LUT_FNS = ["exp", "one_plus_exp_neg", "sigmoid", "gelu_inner"]


@pytest.mark.parametrize("shape", LUT_SHAPES)
@pytest.mark.parametrize("fn", LUT_FNS)
def test_lut_kernel_vs_ref(shape, fn):
    x = jax.random.normal(jax.random.PRNGKey(5), shape) * 3
    got = ops.lut_apply(x, fn)
    want = ref.lut_apply_ref(x, fn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("fmt", ["BBFP(10,5)", "BFP10"])
def test_lut_kernel_formats(fmt):
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 512)) * 5
    got = ops.lut_apply(x, "exp", fmt)
    want = ref.lut_apply_ref(x, "exp", fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_lut_inside_jit():
    """regression: LUT table construction under an ambient jit trace."""
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 512))

    @jax.jit
    def f(x):
        return ops.lut_apply(x, "sigmoid")

    np.testing.assert_allclose(np.asarray(f(x)),
                               np.asarray(ref.lut_apply_ref(x, "sigmoid")),
                               rtol=0, atol=0)


def test_kernel_accuracy_vs_true_values():
    """the quantised matmul approximates the fp matmul within format error."""
    a = jax.random.normal(jax.random.PRNGKey(8), (256, 256))
    b = jax.random.normal(jax.random.PRNGKey(9), (256, 128))
    true = a @ b
    for fmt, tol in [("BBFP(6,3)", 0.02), ("BBFP(4,2)", 0.08), ("BFP4", 0.25)]:
        got = ops.bbfp_matmul(a, b, fmt)
        rel = float(jnp.linalg.norm(got - true) / jnp.linalg.norm(true))
        assert rel < tol, (fmt, rel)
