"""Paged KV-block allocator + prefill shapes (runtime/paged_kv.py).

Acceptance criteria of the paged-KV rework:
  * paged-vs-dense-vs-sequential decode parity: token-for-token identical
    outputs for a ragged mix of prompt lengths (including a prompt that
    spans multiple pages and decode steps that cross page boundaries);
  * step() stays ONE jitted decode per tick in both layouts;
  * prefill compilations: the dense layout's bucket ladder is bounded by
    the number of power-of-two BUCKETS, and the paged layout's incremental
    chunked prefill compiles exactly ONE shape for any prompt length;
  * the allocator's reservation accounting: admission waits (FIFO) when the
    page pool cannot cover a request's worst case, decode-time appends never
    fail, retirement returns pages to the pool.

(Prefix sharing / copy-on-write refcounts live in tests/test_prefix_cache.py.)
"""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch.serve import generate
from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime import paged_kv as PK
from repro.runtime.batcher import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------------------------------
# allocator unit tests (host-side, no model)
# ---------------------------------------------------------------------------

def test_allocator_admit_append_release():
    al = PK.PagedKVAllocator(n_pages=6, page=4, n_slots=2)
    assert al.sentinel == 6
    # admit: prompt 5 rows -> 2 pages now, worst case 11 rows -> 3 reserved
    pids = al.admit(0, prompt_rows=5, total_rows=11)
    assert len(pids) == 2 and al.used_count == 2
    assert al.committed == 1          # one more page promised to slot 0
    # rows 5..7 live in the existing page; row 8 appends the reserved one
    assert al.ensure_row(0, 5) is None
    assert al.ensure_row(0, 7) is None
    idx, pid = al.ensure_row(0, 8)
    assert idx == 2 and pid not in pids
    assert al.committed == 0 and al.used_count == 3
    freed = al.release(0)
    assert sorted(freed) == sorted(pids + [pid])
    assert al.used_count == 0 and al.free_count == 6


def test_allocator_can_admit_respects_reservations():
    al = PK.PagedKVAllocator(n_pages=4, page=4, n_slots=2)
    al.admit(0, prompt_rows=4, total_rows=16)   # 1 page now, 4 reserved
    # 3 free pages but all are committed to slot 0's future appends
    assert al.free_count == 3 and al.committed == 3
    assert not al.can_admit(4)                  # even one page is too many
    al.release(0)
    assert al.can_admit(16)


def test_pages_for():
    assert PK.pages_for(1, 32) == 1
    assert PK.pages_for(32, 32) == 1
    assert PK.pages_for(33, 32) == 2
    assert PK.pages_for(64, 32) == 2


# ---------------------------------------------------------------------------
# end-to-end parity: paged vs dense vs sequential
# ---------------------------------------------------------------------------

def test_paged_matches_dense_and_sequential():
    """Ragged mix (one prompt spanning 2 pages, decode crossing a page
    boundary): identical tokens in all FOUR regimes — sequential reference,
    dense slab, paged-fp pool, paged-PACKED pool (int8 codes + shared
    exponents) — and ONE decode per tick. All regimes run with the
    BBFP(6,3) KV-cache format, so the packed store's quantise-on-scatter
    sees values already on the grid and is bit-identical to the fp pool."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    lens = [5, 9, 30]                  # 30 spans pages 0-1; +6 crosses row 32
    prompts = [jax.random.randint(jax.random.fold_in(KEY, i), (n,), 0, cfg.vocab)
               for i, n in enumerate(lens)]
    gen = 6
    refs = [generate(cfg, params, p[None, :], qcfg, gen_len=gen)[0].tolist()
            for p in prompts]

    outs = {}
    variants = [("dense", "dense", "fp"), ("paged", "paged", "fp"),
                ("packed", "paged", "packed")]
    for name, layout, storage in variants:
        bat = ContinuousBatcher(cfg, params, qcfg, n_slots=3, max_len=64,
                                kv_layout=layout, kv_storage=storage)
        calls = []
        inner = bat._decode
        bat._decode = lambda *a: (calls.append(1), inner(*a))[1]
        for i, p in enumerate(prompts):
            bat.submit(Request(rid=i, prompt=p, max_new=gen))
        ticks = 0
        while bat.queue or any(r is not None for r in bat.slot_req):
            before = len(calls)
            assert bat.step(), "live requests must decode"
            ticks += 1
            # exactly ONE jitted decode per tick, however ragged the batch
            assert len(calls) == before + 1
        assert bat.decode_calls == ticks == len(calls)
        outs[name] = {r.rid: r.out_tokens[:gen] for r in bat.finished}
        if layout == "paged":
            # retirement returned every page to the pool
            assert bat.alloc.used_count == 0
            assert bool(jnp.all(bat.cache["block_table"] == bat.alloc.sentinel))
    for i, ref in enumerate(refs):
        assert outs["dense"][i] == ref, (i, outs["dense"][i], ref)
        assert outs["paged"][i] == ref, (i, outs["paged"][i], ref)
        assert outs["packed"][i] == ref, (i, outs["packed"][i], ref)


def test_prefill_traces_bounded_by_buckets_dense():
    """Dense layout keeps the bucket ladder: 8 distinct prompt lengths but
    only 3 power-of-two buckets -> exactly 3 prefill compilations
    (max_new=1 retires at admission: prefill-only)."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=32,
                            min_prefill_bucket=4, kv_layout="dense")
    lens = [3, 4, 5, 6, 7, 9, 11, 13]          # buckets {4, 8, 16}
    assert len(set(lens)) == 8
    for i, n in enumerate(lens):
        bat.submit(Request(rid=i, prompt=jnp.arange(n, dtype=jnp.int32),
                           max_new=1))
    finished, _ = bat.run()
    assert len(finished) == 8
    assert {bat._bucket(n) for n in lens} == {4, 8, 16}
    assert bat.prefill_traces == 3             # buckets, not distinct lengths
    assert bat.decode_calls == 0               # all retired at prefill


def test_chunked_prefill_traces_o1_paged():
    """Paged layout replaced the bucket ladder with incremental chunked
    prefill: ONE compiled shape for every prompt length (tail chunks pad
    to the chunk width), and ceil(p_len/chunk) chunk steps per prompt."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=64,
                            prefill_chunk=8)
    lens = [3, 4, 5, 6, 7, 9, 11, 13, 17, 26]  # many lengths, one shape
    for i, n in enumerate(lens):
        bat.submit(Request(rid=i, prompt=jnp.arange(n, dtype=jnp.int32),
                           max_new=1))
    finished, _ = bat.run()
    assert len(finished) == len(lens)
    assert bat.prefill_traces == 1             # ONE chunk shape, any length
    assert bat.chunk_prefill_calls == sum(-(-n // 8) for n in lens)
    assert bat.decode_calls == 0               # all retired at prefill
    # transiently-admitted pages all returned (max_new=1 retires at prefill)
    assert bat.alloc.used_count == 0


def test_page_exhaustion_queues_fifo():
    """pool of ONE page: requests serialize through it and all finish."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=32,
                            n_pages=1)
    for i in range(3):
        bat.submit(Request(rid=i, prompt=jnp.arange(6, dtype=jnp.int32) + i,
                           max_new=4))
    seen_in_use = []
    ticks = 0
    while bat.queue or any(r is not None for r in bat.slot_req):
        assert bat.step() or not bat.queue
        seen_in_use.append(bat.alloc.used_count)
        ticks += 1
        assert ticks < 100
    assert len(bat.finished) == 3
    assert all(len(r.out_tokens) == 4 for r in bat.finished)
    assert max(seen_in_use) <= 1               # never over the budget


def test_submit_rejects_request_larger_than_page_pool():
    """a request whose worst-case page count exceeds the whole pool could
    never be admitted — it must be rejected at submit(), not spin forever
    at the head of the FIFO queue."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=64,
                            n_pages=1)
    with pytest.raises(ValueError, match="page pool budget"):
        bat.submit(Request(rid=0, prompt=jnp.arange(40, dtype=jnp.int32),
                           max_new=4))          # 43 rows -> 2 pages > pool 1
    # a one-page request still fits the same pool
    bat.submit(Request(rid=1, prompt=jnp.arange(8, dtype=jnp.int32),
                       max_new=4))
    finished, _ = bat.run()
    assert len(finished) == 1 and len(finished[0].out_tokens) == 4


def test_paged_cache_memory_tracks_load():
    """the paged store admits a smaller pool than dense n_slots*max_len and
    kv_stats reports bytes-in-use proportional to allocated pages."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    dense = ContinuousBatcher(cfg, params, Q.FP, n_slots=4, max_len=128,
                              kv_layout="dense")
    paged = ContinuousBatcher(cfg, params, Q.FP, n_slots=4, max_len=128,
                              n_pages=4)      # 1/4 of the dense capacity
    assert paged.kv_stats()["kv_store_bytes"] == \
        dense.kv_stats()["kv_store_bytes"] // 4
    paged.submit(Request(rid=0, prompt=jnp.arange(40, dtype=jnp.int32),
                         max_new=4))
    paged._admit()
    st = paged.kv_stats()
    assert st["pages_in_use"] == 2             # 40 rows -> 2 pages of 32
    assert st["kv_bytes_in_use"] == 2 * st["kv_store_bytes"] // 4


def test_init_paged_cache_rejects_non_transformer():
    cfg = configs.smoke_config("mamba2_2_7b")
    with pytest.raises(NotImplementedError, match="transformer"):
        PK.init_paged_cache(cfg, 2, 32, n_pages=2)


# ---------------------------------------------------------------------------
# packed page storage (int8 codes + shared exponents)
# ---------------------------------------------------------------------------

def test_packed_storage_bytes_ratio():
    """deterministic byte accounting (the CI bench gate mirrors this):
    packed pages (int8 code + int8 per-32-block exponent) hold <= 0.55x the
    bytes of the bf16 fp pool. NOTE the 8-bit code is the information floor
    of BBFP(6,3) (1 sign + 1 flag + 6 mantissa bits): vs a bf16 store the
    ratio can never go below ~0.52, only vs an fp32 store would it be ~0.26."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    fp = ContinuousBatcher(cfg, params, qcfg, n_slots=2, max_len=64)
    pk = ContinuousBatcher(cfg, params, qcfg, n_slots=2, max_len=64,
                           kv_storage="packed")
    r = pk.kv_stats()["kv_store_bytes"] / fp.kv_stats()["kv_store_bytes"]
    assert 0.5 <= r <= 0.55, r
    # the packed pool's leaves really are int8
    dtypes = {x.dtype for x in jax.tree.leaves(pk.cache["layers"])}
    assert dtypes == {jnp.dtype(jnp.int8)}, dtypes


def test_packed_storage_validation():
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    with pytest.raises(ValueError, match="kv_cache"):
        ContinuousBatcher(cfg, params, Q.FP, kv_storage="packed")
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatcher(cfg, params, Q.QuantConfig(kv_cache="BBFP(6,3)"),
                          kv_layout="dense", kv_storage="packed")
    # a format that does not fit the int8 code (BBFP(10,5) needs 11+1 bits)
    with pytest.raises(ValueError, match="int8-codable"):
        PK.init_paged_cache(cfg, 2, 32, n_pages=2, storage="packed",
                            kv_fmt=Q.QuantConfig(kv_cache="BBFP(10,5)").kv_fmt)


def test_packed_storage_mla_decodes_close_to_fp():
    """MLA's compressed latent is deliberately NOT quantised on the fp
    paths; packed storage is the explicit opt-in that stores it as int8
    codes. So packed-MLA only tracks fp-MLA approximately (BBFP(6,3) is
    near-lossless) rather than token-for-token like GQA."""
    cfg = configs.smoke_config("deepseek_v2_lite_16b")
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    prompts = [jax.random.randint(jax.random.fold_in(KEY, i), (n,), 0, cfg.vocab)
               for i, n in enumerate([6, 11])]
    outs = {}
    for storage in ("fp", "packed"):
        bat = ContinuousBatcher(cfg, params, qcfg, n_slots=2, max_len=48,
                                kv_storage=storage)
        for i, p in enumerate(prompts):
            bat.submit(Request(rid=i, prompt=p, max_new=5))
        finished, _ = bat.run()
        assert len(finished) == 2
        outs[storage] = {r.rid: r.out_tokens for r in finished}
    agree = sum(a == b for i in outs["fp"]
                for a, b in zip(outs["fp"][i], outs["packed"][i]))
    total = sum(len(v) for v in outs["fp"].values())
    assert agree >= 0.6 * total, (outs, agree, total)
