"""Engine layering: Scheduler policy (mock runner), batched multi-slot
chunked prefill, and preemption + recompute end-to-end.

Acceptance criteria of the engine split (Scheduler / KVCacheManager /
ModelRunner behind the ContinuousBatcher façade):
  * the Scheduler is pure host Python — its whole admission/preemption
    policy runs here against a MOCK runner (no jax, no params);
  * batched multi-slot chunked prefill keeps ONE compiled prefill shape
    while running a multi-request burst in max-chunks lockstep steps
    instead of sum-of-chunks sequential calls — and stays token-identical
    to sequential decoding;
  * an oversubscribed page pool completes every request via preemption +
    recompute, bit-exact vs an unconstrained run, with shared pages
    surviving the eviction of one of their readers (refcount > 0);
  * a request the strict batcher rejects at submit (worst case > pool)
    is accepted under preempt=True and completes when eos lands early.
"""
import numpy as np
import pytest

from repro.runtime import paged_kv as PK
from repro.runtime.kv_manager import KVCacheManager
from repro.runtime.scheduler import Scheduler


class FakeReq:
    """Host-only request for mock-runner scheduler tests (no jax arrays)."""

    def __init__(self, rid, n_prompt, max_new, priority=0):
        self.rid = rid
        self.prompt = np.arange(n_prompt, dtype=np.int32) + 100 * rid
        self.max_new = max_new
        self.priority = priority
        self.out_tokens: list[int] = []
        self.done = False


class MockRunner:
    """Stand-in execution layer: 'prefills' and 'decodes' deterministic
    tokens with no model, so the tick protocol (schedule -> seat ->
    secure_appends -> decode -> note_decoded/retire) runs at full speed
    and the Scheduler's policy is observable in isolation."""

    def __init__(self):
        self.prefills = []                   # (rid, start_row, n_rows)

    def prefill(self, adm) -> int:
        self.prefills.append((adm.req.rid, adm.start_row, len(adm.tokens)))
        return 1000 + adm.req.rid

    def decode(self, req) -> int:
        return 2000 + req.rid * 10 + len(req.out_tokens)


def drive_tick(sched: Scheduler, runner: MockRunner, finished: list):
    """One façade tick against the mock runner."""
    admissions, _ = sched.schedule()
    for adm in admissions:
        if adm.resume:
            sched.seat(adm.slot, len(adm.tokens))
            continue
        tok = runner.prefill(adm)
        adm.req.out_tokens.append(tok)
        if len(adm.req.out_tokens) >= adm.req.max_new:
            adm.req.done = True
            finished.append(adm.req)
            sched.retire(adm.slot)
        else:
            sched.seat(adm.slot, len(adm.tokens))
    if not sched._live():
        return
    sched.secure_appends()
    retired = []
    for s in sched._live():
        req = sched.slot_req[s]
        req.out_tokens.append(runner.decode(req))
        if len(req.out_tokens) >= req.max_new:
            req.done = True
            finished.append(req)
            retired.append(s)
    sched.note_decoded()
    for s in retired:
        sched.retire(s)


def drive(sched, runner, max_ticks=300):
    finished = []
    ticks = 0
    while (sched.queue or sched._live()) and ticks < max_ticks:
        drive_tick(sched, runner, finished)
        ticks += 1
    return finished, ticks


def _engine(n_pages, n_slots, *, page=4, preempt=True, prefix=True):
    kv = KVCacheManager(n_pages, page, n_slots,
                        strict_reserve=not preempt, retain=prefix)
    return kv, Scheduler(kv, n_slots, page_size=page, preempt=preempt,
                         prefix_cache=prefix)


# ---------------------------------------------------------------------------
# Scheduler policy with a mock runner (pure host, no jax)
# ---------------------------------------------------------------------------

def test_scheduler_fifo_admission_and_retire():
    kv, sched = _engine(n_pages=16, n_slots=2, preempt=False)
    runner = MockRunner()
    for i in range(4):
        req = FakeReq(i, n_prompt=6, max_new=3)
        sched.submit(req, req.prompt)
    finished, _ = drive(sched, runner)
    assert [r.rid for r in finished] == [0, 1, 2, 3]     # FIFO order
    assert all(len(r.out_tokens) == 3 for r in finished)
    assert kv.used_count == 0                            # everything drained
    assert [p[0] for p in runner.prefills] == [0, 1, 2, 3]


def test_scheduler_append_exhaustion_preempts_latest_arrival():
    """Relaxed capacity oversubscribes the pool: admission charges only
    prompt pages, so when both slots need a decode append and the pool is
    dry the LATEST-arrived sequence is evicted, requeued with its generated
    tokens, and readmitted (recompute) once pages free up."""
    # page=4; prompts of 7 rows = 2 pages each; pool of 4 admits both.
    # max_new=8 -> rows grow to 14 -> each needs a 3rd and 4th page.
    kv, sched = _engine(n_pages=4, n_slots=2)
    runner = MockRunner()
    a, b = FakeReq(0, 7, 8), FakeReq(1, 7, 8)
    sched.submit(a, a.prompt)
    sched.submit(b, b.prompt)
    finished, _ = drive(sched, runner)
    assert {r.rid for r in finished} == {0, 1}
    assert all(len(r.out_tokens) == 8 for r in finished)
    assert sched.preemptions >= 1
    assert sched.recomputed_tokens > 0
    # the victim was the later arrival (rid 1): rid 0 never re-prefilled
    starts = [(rid, start) for rid, start, _ in runner.prefills]
    assert starts[0] == (0, 0) and starts[1] == (1, 0)
    assert all(rid == 1 for rid, _ in starts[2:] if _ is not None)
    assert kv.used_count == 0


def test_scheduler_priority_preempts_admission_blocked_head():
    """A higher-priority head evicts the lowest-ranked running sequence
    when the pool cannot admit it; equal-priority FIFO traffic never
    admission-preempts (the head arrived last)."""
    kv, sched = _engine(n_pages=4, n_slots=2)
    runner = MockRunner()
    lo = FakeReq(0, 14, 4)                     # 4 pages: fills the pool
    sched.submit(lo, lo.prompt)
    drive_tick(sched, runner, [])
    assert sched.slot_req[0] is lo
    # same-priority head waits (no admission preemption for FIFO traffic)
    peer = FakeReq(1, 8, 4)
    sched.submit(peer, peer.prompt)
    admissions, evicted = sched.schedule()
    assert admissions == [] and evicted == [] and sched.slot_req[0] is lo
    # a higher-priority head evicts the running low-priority sequence
    hi = FakeReq(2, 8, 4, priority=5)
    sched.submit(hi, hi.prompt)
    admissions, evicted = sched.schedule()
    assert evicted == [0] and sched.preemptions == 1
    assert [a.req.rid for a in admissions] == [2]
    assert lo._resume is not None              # requeued for recompute
    # the victim re-enters the queue ahead of the equal-priority peer
    # that arrived after it
    assert [r.rid for r in sched.queue] == [0, 1]


def test_cost_aware_victim_prefers_cheap_recompute_over_rank():
    """Victim selection is by RECOMPUTE COST, not pure rank: a sequence
    whose pages are all still radix-indexed (free to readmit — the LRU
    keeps them) is evicted ahead of a lower-ranked one that would have to
    re-prefill rows. page=4: rid 0's 8-row prompt is 2 FULL pages (both
    radix-registered at admission -> cost 8 - 2*4 = 0); rid 1's 7-row
    prompt registers only 1 full page (cost 7 - 4 = 3). When both need an
    append and the 4-page pool is dry, rank order would evict rid 1 (later
    arrival) — cost order must evict rid 0."""
    kv, sched = _engine(n_pages=4, n_slots=2)
    runner = MockRunner()
    a, b = FakeReq(0, 8, 8), FakeReq(1, 7, 8)
    sched.submit(a, a.prompt)
    sched.submit(b, b.prompt)
    finished = []
    drive_tick(sched, runner, finished)        # both admit; appends collide
    assert sched.preemptions == 1
    assert a._resume is not None               # the CHEAP victim, not b
    assert b._resume is None and b in sched.slot_req
    fin, _ = drive(sched, runner)
    assert {r.rid for r in finished + fin} == {0, 1}
    assert all(len(r.out_tokens) == 8 for r in (a, b))
    assert kv.used_count == 0


def test_preempted_resume_tokens_are_prompt_plus_generated():
    """The readmission prompt is prompt + out_tokens[:-1]: the last token
    was never written to KV and becomes the resumed cur_tok."""
    kv, sched = _engine(n_pages=4, n_slots=1, page=4)
    runner = MockRunner()
    req = FakeReq(7, 6, 5)
    sched.submit(req, req.prompt)
    finished = []
    drive_tick(sched, runner, finished)        # prefill + first decode
    drive_tick(sched, runner, finished)        # second decode
    assert len(req.out_tokens) == 3
    sched.preempt(0)
    assert req._resume.tolist() == \
        req.prompt.tolist() + req.out_tokens[:-1]
    assert len(req._resume) == 6 + 3 - 1
    finished, _ = drive(sched, runner)
    assert len(finished) == 1 and len(req.out_tokens) == 5


def test_sole_runner_that_cannot_append_fails_loudly():
    """preempt mode admits requests whose worst case exceeds the pool (an
    early eos may complete them); if no eos arrives the engine must fail
    the no-progress case instead of preempt-thrashing forever."""
    kv, sched = _engine(n_pages=2, n_slots=1, page=4)
    runner = MockRunner()
    req = FakeReq(0, 4, 9)                     # worst case 12 rows = 3 pages
    sched.submit(req, req.prompt)              # accepted: prompt+1 fits
    with pytest.raises(RuntimeError,
                       match="cannot make progress|can never be admitted"):
        drive(sched, runner)


def test_scheduler_rejects_preempt_with_strict_kv():
    kv = KVCacheManager(4, 4, 1, strict_reserve=True)
    with pytest.raises(AssertionError, match="relaxed-capacity"):
        Scheduler(kv, 1, preempt=True)
    with pytest.raises(AssertionError, match="paged"):
        Scheduler(None, 1, preempt=True)


# ---------------------------------------------------------------------------
# batched multi-slot chunked prefill + preemption, end-to-end (real model)
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.serve import generate  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.quant import linear as Q  # noqa: E402
from repro.runtime.batcher import ContinuousBatcher, Request  # noqa: E402

KEY = jax.random.PRNGKey(31)
PAGE = PK.PAGE_SIZE


def test_batched_prefill_compresses_a_burst():
    """A 4-request burst admits through lockstep batched prefill: ONE
    compiled shape, per-request chunk work unchanged, but the number of
    compiled-call launches is the max chunk count, not the sum — and
    tokens stay identical to sequential decoding."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    lens = [40, 50, 60, 70]                    # 2..3 chunks each at chunk=32
    prompts = [jax.random.randint(jax.random.fold_in(KEY, i), (n,), 0,
                                  cfg.vocab) for i, n in enumerate(lens)]
    gen = 4
    refs = [generate(cfg, params, p[None, :], Q.FP, gen_len=gen)[0].tolist()
            for p in prompts]
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=4, max_len=128)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    assert bat.step()                          # the whole burst admits here
    per_req = [-(-n // 32) for n in lens]      # ceil(p_len / chunk)
    assert bat.chunk_prefill_calls == sum(per_req)      # work items kept
    assert bat.prefill_steps == max(per_req)   # but launched in lockstep
    assert bat.prefill_steps < bat.chunk_prefill_calls  # burst really batched
    assert bat.prefill_traces == 1             # ONE compiled prefill shape
    finished, _ = bat.run()
    got = {r.rid: r.out_tokens[:gen] for r in finished}
    for i, ref in enumerate(refs):
        assert got[i] == ref, (i, got[i], ref)


@pytest.mark.parametrize("storage", ["fp", "packed"])
def test_oversubscribed_pool_completes_via_preemption(storage):
    """The tentpole capability: a pool holding fewer pages than the
    workload's worst case completes EVERY request via preemption +
    recompute, token-identical to an unconstrained run, and pages shared
    with a preempted reader survive its eviction (refcount > 0)."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    shared = jax.random.randint(jax.random.fold_in(KEY, 99), (PAGE,), 0,
                                cfg.vocab)
    prompts = [jnp.concatenate([shared, jax.random.randint(
        jax.random.fold_in(KEY, i), (n,), 0, cfg.vocab)])
        for i, n in enumerate([7, 11, 15])]    # 39..47 rows: 2 pages each
    gen = 30                                   # grows every request past 64
    outs = {}
    for n_pages in (None, 6):                  # unconstrained, then starved
        bat = ContinuousBatcher(cfg, params, qcfg, n_slots=3, max_len=128,
                                n_pages=n_pages, kv_storage=storage,
                                preempt=True)
        for i, p in enumerate(prompts):
            bat.submit(Request(rid=i, prompt=p, max_new=gen))
        shared_alive = []
        ticks = 0
        while (bat.queue or any(r is not None for r in bat.slot_req)) \
                and ticks < 400:
            bat.step()
            ticks += 1
            if n_pages == 6 and bat.preemptions:
                # the shared prefix page must survive its readers' eviction
                live = [s for s, r in enumerate(bat.slot_req)
                        if r is not None]
                for s in live:
                    pid = bat.alloc.pages[s][0]
                    shared_alive.append(bat.alloc.refcount[pid] >= 1)
        assert len(bat.finished) == 3
        assert all(len(r.out_tokens) == gen for r in bat.finished)
        outs[n_pages] = {r.rid: r.out_tokens for r in bat.finished}
        if n_pages == 6:
            assert bat.preemptions >= 1, "starved pool must have preempted"
            assert bat.recomputed_tokens > 0
            assert all(shared_alive) and shared_alive
            assert bat.kv_stats()["preemptions"] == bat.preemptions
        assert bat.alloc.used_count == 0       # fully drained either way
    assert outs[None] == outs[6], storage      # preemption is bit-exact


def test_strict_submit_reject_completes_under_preempt():
    """A request whose worst case exceeds the whole pool is rejected at
    submit by the strict batcher; preempt mode admits it optimistically
    and completes it bit-exact when eos lands before the pool runs out."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    prompt = jax.random.randint(KEY, (8,), 0, cfg.vocab)
    probe = generate(cfg, params, prompt[None, :], Q.FP, gen_len=12)[0]
    eos = int(probe[6])                        # greedy decode WILL emit this
    big = 120                                  # worst case 127 rows = 4 pages
    strict = ContinuousBatcher(cfg, params, Q.FP, n_slots=1, max_len=128,
                               n_pages=2)
    with pytest.raises(ValueError, match="page pool budget"):
        strict.submit(Request(rid=0, prompt=prompt, max_new=big))
    ref = ContinuousBatcher(cfg, params, Q.FP, n_slots=1, max_len=128,
                            eos_id=eos)        # unconstrained reference
    ref.submit(Request(rid=0, prompt=prompt, max_new=big))
    ref_out = ref.run()[0][0].out_tokens
    assert ref_out[-1] == eos and len(ref_out) <= 8   # eos really fired
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=1, max_len=128,
                            n_pages=2, eos_id=eos, preempt=True)
    bat.submit(Request(rid=0, prompt=prompt, max_new=big))   # accepted now
    finished, _ = bat.run()
    assert len(finished) == 1
    assert finished[0].out_tokens == ref_out   # bit-exact completion


def test_preempt_requires_paged_layout():
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatcher(cfg, params, Q.FP, kv_layout="dense", preempt=True)
