"""Roofline HLO analyzer: known-flops cases + collective wire-cost math."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def test_scan_matmul_flops():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    cost = H.analyze(c.as_text())
    expected = 2 * 128 * 256 * 256 * 10
    assert 0.95 < cost.flops / expected < 1.1, cost.flops


def test_nested_scan_flops():
    def f(x, w):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=4)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    cost = H.analyze(jax.jit(f).lower(x, w).compile().as_text())
    expected = 2 * 64 * 64 * 64 * 12
    assert 0.9 < cost.flops / expected < 1.2, cost.flops


def test_grad_flops_triple_forward():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)
    g = jax.grad(loss)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    cost = H.analyze(jax.jit(g).lower(w, x).compile().as_text())
    fwd = 2 * 128 * 256 * 256
    # grad = fwd + 2 matmuls in bwd ~= 3x fwd (one of the bwd dots is wrt w)
    assert 1.8 < cost.flops / fwd < 3.5, cost.flops / fwd


_COLLECTIVE_HLO = """HloModule test

ENTRY %main.1 (p0.1: f32[1024]) -> f32[1024] {
  %p0.1 = f32[1024]{0} parameter(0)
  %all-reduce.1 = f32[1024]{0} all-reduce(%p0.1), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.1 = f32[1024]{0} all-gather(%p0.1), replica_groups={{0,1}}, dimensions={0}
  ROOT %add.9 = f32[1024]{0} add(%all-reduce.1, %all-gather.1)
}
"""


def test_collective_wire_bytes():
    cost = H.analyze(_COLLECTIVE_HLO, total_devices=4)
    ar = 2 * 4096 * 3 / 4          # all-reduce: 2*s*(n-1)/n, n=4
    ag = 4096 * 1 / 2              # all-gather: s*(n-1)/n, n=2
    assert abs(cost.coll_bytes - (ar + ag)) < 1e-6, cost.coll_by_kind


_WHILE_HLO = """HloModule t

%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64]{0} get-tuple-element(%p), index=1
  %ar = f32[64]{0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64]{0}) tuple(%ip, %ar)
}

%cond.1 (p.2: (s32[], f32[64])) -> pred[] {
  %p.2 = (s32[], f32[64]{0}) parameter(0)
  %i.2 = s32[] get-tuple-element(%p.2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i.2, %n), direction=LT
}

ENTRY %main.2 (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64]{0}) tuple(%zero, %a)
  %w = (s32[], f32[64]{0}) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_scales_collectives():
    cost = H.analyze(_WHILE_HLO, total_devices=2)
    per_trip = 2 * 256 * 1 / 2     # all-reduce of 256 bytes over 2 devices
    assert abs(cost.coll_bytes - 7 * per_trip) < 1e-6


def test_tuple_index_comment_regression():
    """instruction results with /*index=N*/ comments must still parse."""
    hlo = """HloModule r

ENTRY %main.3 (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %t = (f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, /*index=5*/f32[8]{0}) tuple(%a, %a, %a, %a, %a, %a)
  ROOT %o = f32[8]{0} get-tuple-element(%t), index=0
}
"""
    comps = H.parse_computations(hlo)
    lines = comps["main.3"]
    assert any(H._INSTR_RE.match(l) and H._INSTR_RE.match(l).group(3) == "tuple"
               for l in lines)
