"""Shared-prefix copy-on-write pages + incremental chunked prefill.

Acceptance criteria of the page-native scheduler rework:
  * a batch of requests sharing a >= 64-token page-aligned prompt prefix
    stores each shared 32-row page exactly ONCE (refcounts + kv_stats
    logical-vs-physical bytes) yet decodes token-for-token identically to
    independent sequential decoding — for fp AND packed storage, GQA and
    MLA;
  * prefix-hit admissions measurably skip the shared pages' prefill compute
    (chunk_prefill_calls) and the chunked-prefill compile count is O(1) in
    prompt length;
  * allocator refcount lifecycle: admit-with-shared-prefix, then EITHER
    retire order returns the pool to fully free (and empties the prefix
    index); a hypothesis property sweep drives random admit/decode/release
    schedules against the invariants (deterministic fallback when
    hypothesis is absent, like test_bbfp_format.py).
"""
import random

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st

    def seeds(n):
        return settings(max_examples=n, deadline=None)(
            given(st.integers(0, 2**32 - 1)))
except ModuleNotFoundError:
    # bare containers (no network) fall back to a deterministic seed sweep
    def seeds(n):
        return pytest.mark.parametrize("seed", [7 * i + 1 for i in range(n)])

from repro import configs
from repro.launch.serve import generate
from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime import paged_kv as PK
from repro.runtime.batcher import ContinuousBatcher, Request, kv_rows_needed

KEY = jax.random.PRNGKey(23)
PAGE = PK.PAGE_SIZE


def _keys(tokens, page):
    """Cumulative full-page prefix keys, as the batcher derives them."""
    return [tuple(tokens[:(i + 1) * page]) for i in range(len(tokens) // page)]


def _prompts_with_shared_prefix(cfg, prefix_len, suffix_lens, salt=0):
    prefix = jax.random.randint(jax.random.fold_in(KEY, 100 + salt),
                                (prefix_len,), 0, cfg.vocab)
    return [jnp.concatenate([
        prefix, jax.random.randint(jax.random.fold_in(KEY, salt + i),
                                   (n,), 0, cfg.vocab)])
        for i, n in enumerate(suffix_lens)]


# ---------------------------------------------------------------------------
# allocator refcount lifecycle (host-side, no model)
# ---------------------------------------------------------------------------

def test_refcount_lifecycle_both_retire_orders():
    """admit -> register -> admit-with-shared-prefix; whichever of the pair
    retires first, shared pages survive until the second release and the
    pool then returns to fully free with an empty prefix index."""
    for retire_first in (0, 1):
        al = PK.PagedKVAllocator(n_pages=8, page=4, n_slots=2)
        toks_a = list(range(10))                      # 2 full pages + tail
        a = al.admit(0, prompt_rows=10, total_rows=12)
        assert len(a) == 3 and [al.refcount[p] for p in a] == [1, 1, 1]
        al.register_prefix(_keys(toks_a, 4), a[:2])
        # same 8-token prefix, longer prompt: both full pages hit
        toks_b = toks_a[:8] + [90, 91, 92, 93, 94]
        hit = al.match_prefix(_keys(toks_b, 4)[: (13 - 1) // 4])
        assert hit == a[:2]
        b = al.admit(1, prompt_rows=13, total_rows=14, shared=hit)
        assert b[:2] == a[:2] and b[2] not in a
        assert [al.refcount[p] for p in a[:2]] == [2, 2]
        assert al.shared_count == 2 and al.logical_count == 3 + 4
        assert al.used_count == 5                     # shared stored once
        freed_1 = al.release(retire_first)
        # the other slot still references the shared pages: NOT freed yet
        assert not set(freed_1) & set(a[:2])
        assert [al.refcount[p] for p in a[:2]] == [1, 1]
        assert al.match_prefix(_keys(toks_a, 4)) == a[:2]   # still resident
        al.release(1 - retire_first)
        assert al.used_count == 0 and al.free_count == 8
        assert al.committed == 0 and al.shared_count == 0
        assert al._prefix_index == {} and al._page_key == {}
        assert al.refcount == [0] * 8


def test_can_admit_counts_only_newly_allocated_pages():
    """a prefix-heavy request must be admissible when the pool only covers
    its NEW pages — the whole point of sharing under memory pressure."""
    al = PK.PagedKVAllocator(n_pages=4, page=4, n_slots=2)
    toks = list(range(16))
    a = al.admit(0, prompt_rows=12, total_rows=12)    # 3 pages, no reserve
    al.register_prefix(_keys(toks[:12], 4), a)
    assert al.free_count == 1 and al.committed == 0
    # 16-token prompt, 16 total rows -> 4 pages; 3 are resident prefix hits
    hit = al.match_prefix(_keys(toks, 4)[: (16 - 1) // 4])
    assert hit == a                                   # all 3 full pages
    assert not al.can_admit(16)                       # 4 new > 1 free
    assert al.can_admit(16, n_shared=len(hit))        # 1 new <= 1 free
    b = al.admit(1, prompt_rows=16, total_rows=16, shared=hit)
    assert al.free_count == 0 and b[:3] == a


@seeds(25)
def test_allocator_invariants_random_schedules(seed):
    """property sweep: random admit(+prefix match/register)/decode/release
    schedules keep the allocator's books consistent, and draining every
    slot always returns the pool to fully free."""
    rng = random.Random(seed)
    page, n_slots = 4, 3
    n_pages = rng.randrange(6, 14)
    al = PK.PagedKVAllocator(n_pages, page, n_slots)
    live = {}                                  # slot -> (host_pos, total)

    def check():
        held = [p for ps in al.pages for p in ps]
        assert al.used_count == len(set(held))
        assert sorted(set(al.free)) == sorted(al.free)       # no dup frees
        assert not set(al.free) & set(held)
        for pid in range(n_pages):
            assert al.refcount[pid] == held.count(pid)
            assert (al.refcount[pid] == 0) == (pid in al.free)
        assert al.committed >= 0
        for key, pid in al._prefix_index.items():
            assert al.refcount[pid] >= 1 and al._page_key[pid] == key
        assert al.logical_count == len(held)
        assert al.shared_count == sum(1 for pid in set(held)
                                      if held.count(pid) > 1)

    for _ in range(40):
        op = rng.randrange(3)
        free_slots = [s for s in range(n_slots) if s not in live]
        if op == 0 and free_slots:
            slot = rng.choice(free_slots)
            # tiny alphabet so prefixes collide across admissions
            p_len = rng.randrange(1, 3 * page + 2)
            toks = [rng.randrange(3) for _ in range(p_len)]
            max_new = rng.randrange(1, page + 2)
            total = kv_rows_needed(p_len, max_new)
            if PK.pages_for(total, page) > n_pages:
                continue
            keys = _keys(toks, page)
            hit = al.match_prefix(keys[: (p_len - 1) // page])
            if al.can_admit(total, n_shared=len(hit)):
                pids = al.admit(slot, p_len, total, shared=hit)
                al.register_prefix(keys, pids[:len(keys)])
                live[slot] = [p_len, total]
        elif op == 1 and live:
            slot = rng.choice(list(live))
            pos, total = live[slot]
            if pos < total:                    # decode writes rows < total
                al.ensure_row(slot, pos)
                live[slot][0] = pos + 1
        elif op == 2 and live:
            slot = rng.choice(list(live))
            al.release(slot)
            del live[slot]
        check()
    for slot in list(live):
        al.release(slot)
        check()
    assert al.free_count == n_pages and al.used_count == 0
    assert al._prefix_index == {} and al.refcount == [0] * n_pages


# ---------------------------------------------------------------------------
# end-to-end: shared-prefix batches vs independent sequential decodes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("storage", ["fp", "packed"])
def test_gqa_shared_prefix_matches_sequential(storage):
    """4 requests sharing a 64-token (2-page) prefix: the shared pages are
    stored exactly once, prefix-hit admissions skip those pages' prefill
    chunks, and every request decodes token-for-token like an independent
    sequential decode."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    suffixes = [5, 9, 13, 17]
    prompts = _prompts_with_shared_prefix(cfg, 2 * PAGE, suffixes)
    gen = 6
    refs = [generate(cfg, params, p[None, :], qcfg, gen_len=gen)[0].tolist()
            for p in prompts]

    bat = ContinuousBatcher(cfg, params, qcfg, n_slots=4, max_len=128,
                            kv_storage=storage)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    assert bat.step()                          # all four admitted this tick
    st_ = bat.kv_stats()
    # each shared 32-row page stored exactly once: 3 followers x 2 pages
    assert st_["pages_shared"] == 2
    assert st_["pages_logical"] - st_["pages_in_use"] == 6
    assert st_["kv_bytes_logical"] > st_["kv_bytes_physical"]
    assert bat.prefix_hit_pages == 6
    assert bat.prefix_hit_rate == pytest.approx(6 / 12)  # 3 pages/prompt
    # prefill compute skipped: leader runs ceil(69/32)=3 chunks, followers
    # only their post-prefix remainder (1 chunk each)
    assert bat.chunk_prefill_calls == 3 + 3 * 1
    assert bat.prefill_traces == 1
    finished, _ = bat.run()
    assert len(finished) == 4
    got = {r.rid: r.out_tokens[:gen] for r in finished}
    for i, ref in enumerate(refs):
        assert got[i] == ref, (storage, i, got[i], ref)
    # retirement drains everything, in whatever order requests finished
    assert bat.alloc.used_count == 0 and bat.alloc.shared_count == 0
    assert bool(jnp.all(bat.cache["block_table"] == bat.alloc.sentinel))


def test_mla_shared_prefix_matches_sequential_fp():
    """MLA (compressed-latent cache): chunked prefill + prefix sharing stay
    token-for-token with sequential decoding on the fp pool. The arch is
    MoE: chunked prefill routes prompt tokens DROPLESS (decode-style),
    while the dense reference prefill uses capacity routing — raise the
    capacity factor so neither drops and the routing maths coincide (same
    workaround as test_ragged_moe_dense_layers_match_sequential)."""
    import dataclasses
    cfg = configs.smoke_config("deepseek_v2_lite_16b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init(cfg, KEY)
    prompts = _prompts_with_shared_prefix(cfg, 2 * PAGE, [5, 9], salt=3)
    gen = 4
    refs = [generate(cfg, params, p[None, :], Q.FP, gen_len=gen)[0].tolist()
            for p in prompts]
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=96)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    finished, _ = bat.run()
    assert bat.prefix_hit_pages == 2           # follower shares both pages
    got = {r.rid: r.out_tokens[:gen] for r in finished}
    for i, ref in enumerate(refs):
        assert got[i] == ref, (i, got[i], ref)
    assert bat.alloc.used_count == 0


def test_mla_packed_sharing_is_deterministic():
    """packed MLA quantises the latent (close-not-equal to fp by design),
    so the parity statement is sharing vs NO-sharing on the same packed
    pool: shared pages hold bit-identical codes, tokens must match."""
    cfg = configs.smoke_config("deepseek_v2_lite_16b")
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    prompts = _prompts_with_shared_prefix(cfg, 2 * PAGE, [5, 9], salt=5)
    outs = {}
    for share in (True, False):
        bat = ContinuousBatcher(cfg, params, qcfg, n_slots=2, max_len=96,
                                kv_storage="packed", prefix_cache=share)
        for i, p in enumerate(prompts):
            bat.submit(Request(rid=i, prompt=p, max_new=4))
        finished, _ = bat.run()
        assert len(finished) == 2
        assert bat.prefix_hit_pages == (2 if share else 0)
        outs[share] = {r.rid: r.out_tokens for r in finished}
    assert outs[True] == outs[False]


# ---------------------------------------------------------------------------
# sharing boundaries
# ---------------------------------------------------------------------------

def test_partial_and_last_pages_never_shared():
    """identical 40-token prompts share only page 0: page 1 is the last
    (partial) page and must stay private to each writer. And identical
    64-token prompts share only page 0: page 1 holds the last prompt token,
    which must rerun through chunk prefill for its logits."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    for p_len, want_shared in ((40, 1), (64, 1), (65, 2)):
        prompt = jax.random.randint(jax.random.fold_in(KEY, p_len),
                                    (p_len,), 0, cfg.vocab)
        bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=128)
        ref = generate(cfg, params, prompt[None, :], Q.FP, gen_len=4)[0].tolist()
        for i in range(2):
            bat.submit(Request(rid=i, prompt=prompt, max_new=4))
        assert bat.step()
        assert bat.kv_stats()["pages_shared"] == want_shared, p_len
        assert bat.prefix_hit_pages == want_shared
        finished, _ = bat.run()
        for r in finished:
            assert r.out_tokens == ref, (p_len, r.out_tokens, ref)


def test_decode_appended_pages_stay_private():
    """two requests sharing a prefix cross a page boundary while decoding:
    the appended pages are private (refcount 1) and never indexed, so the
    divergent generated rows cannot leak into a later admission."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    prompts = _prompts_with_shared_prefix(cfg, PAGE, [PAGE - 2, PAGE - 4],
                                          salt=9)   # 62/60 rows: page 1 partial
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=128)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=8))  # crosses row 64
    finished, _ = bat.run()
    assert len(finished) == 2
    assert bat.prefix_hit_pages == 1
    assert bat.alloc.used_count == 0           # appended pages also drained


def test_prefix_cache_disabled_stores_everything():
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    prompts = _prompts_with_shared_prefix(cfg, 2 * PAGE, [5, 7], salt=11)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=128,
                            prefix_cache=False)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=3))
    assert bat.step()
    st_ = bat.kv_stats()
    assert st_["pages_shared"] == 0
    assert st_["pages_logical"] == st_["pages_in_use"]
    assert bat.prefix_hit_rate == 0.0


def test_kv_rows_needed_contract():
    assert kv_rows_needed(10, 1) == 10
    assert kv_rows_needed(10, 5) == 14
    with pytest.raises(ValueError, match="max_new"):
        kv_rows_needed(10, 0)
