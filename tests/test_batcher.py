"""Continuous-batching scheduler: correctness vs sequential generation."""
import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.serve import generate
from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime.batcher import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(0)


def test_batcher_matches_sequential_generation():
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    prompts = [jax.random.randint(jax.random.fold_in(KEY, i), (8 + 2 * i,),
                                  0, cfg.vocab) for i in range(3)]
    gen = 6
    # sequential reference (one request at a time, same greedy decode)
    refs = [generate(cfg, params, p[None, :], Q.FP, gen_len=gen)[0].tolist()
            for p in prompts]
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=64)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    finished, ticks = bat.run()
    assert len(finished) == 3
    got = {r.rid: r.out_tokens[:gen] for r in finished}
    for i, ref in enumerate(refs):
        assert got[i] == ref, (i, got[i], ref)


def test_batcher_keeps_slots_busy():
    """more requests than slots: admissions refill freed slots mid-run."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=48)
    for i in range(5):
        bat.submit(Request(rid=i, prompt=jnp.arange(6, dtype=jnp.int32) + i,
                           max_new=4))
    finished, ticks = bat.run()
    assert len(finished) == 5
    assert all(len(r.out_tokens) == 4 for r in finished)


def test_batcher_with_bbal_quant_stack():
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(linear="BBFP(4,2)", nonlinear="BBFP(10,5)",
                         kv_cache="BBFP(6,3)")
    bat = ContinuousBatcher(cfg, params, qcfg, n_slots=2, max_len=48)
    bat.submit(Request(rid=0, prompt=jnp.arange(8, dtype=jnp.int32), max_new=5))
    finished, _ = bat.run()
    assert len(finished) == 1 and len(finished[0].out_tokens) == 5
