"""BBFP KV-cache quantisation (beyond-paper serving feature)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as M
from repro.quant import linear as Q

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["llama7b", "gemma3_4b"])
def test_kvq_decode_close_to_bf16_cache(arch):
    cfg = configs.smoke_config(arch)
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab)
    qfp = Q.QuantConfig()                          # fp everything
    qkv = Q.QuantConfig(kv_cache="BBFP(6,3)")      # only the cache quantised

    def run(qcfg):
        _, cache = M.prefill(params, cfg, toks[:, :16], qcfg, max_len=32)
        last = None
        for i in range(16, 24):
            last, cache = M.decode_step(params, cfg, cache, toks[:, i:i + 1], qcfg)
        return last

    ref = run(qfp)
    got = run(qkv)
    err = float(jnp.max(jnp.abs(got - ref)))
    scale = max(float(jnp.max(jnp.abs(ref))), 1.0)
    assert err < 0.05 * scale, (arch, err, scale)   # BBFP(6,3) ~ near-lossless
    # and a crude format must actually change things (sanity that it's wired)
    coarse = run(Q.QuantConfig(kv_cache="BFP4"))
    assert float(jnp.max(jnp.abs(coarse - ref))) > err


def test_kvq_mla_latent_not_quantised():
    """MLA keeps its compressed latent hi-prec (it feeds both k and v via
    up-projections; measured error amplification ~4x vs GQA caches)."""
    cfg = configs.smoke_config("deepseek_v2_lite_16b")
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    _, c1 = M.prefill(params, cfg, toks, Q.QuantConfig(), max_len=20)
    _, c2 = M.prefill(params, cfg, toks, Q.QuantConfig(kv_cache="BBFP(6,3)"),
                      max_len=20)
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)),
                        c1["layers"], c2["layers"])
    assert all(jax.tree.leaves(same))


def test_kvq_greedy_tokens_usually_match():
    from repro.launch.serve import generate
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    prompts = jax.random.randint(KEY, (4, 12), 0, cfg.vocab)
    t_fp = generate(cfg, params, prompts, Q.QuantConfig(), gen_len=8)
    t_kv = generate(cfg, params, prompts, Q.QuantConfig(kv_cache="BBFP(6,3)"),
                    gen_len=8)
    # a random-init smoke model has near-tied logits, so some greedy flips
    # are expected; trained models agree far more (the logit-error test
    # above is the accuracy statement)
    agree = float(jnp.mean((t_fp == t_kv).astype(jnp.float32)))
    assert agree >= 0.6, agree
