"""Fleet routing: prefix-affinity policy (pure host) + EngineFleet
end-to-end determinism.

The router is host-only (hash + load arithmetic), so its policy surface
is tested without any servers. The EngineFleet tests then drive real
AsyncServer replicas over a shared-prefix workload and assert the two
fleet guarantees: (1) DETERMINISM — the same seeded workload produces the
same replica assignment on every run (sha256 route keys, not the salted
builtin hash); (2) AFFINITY — requests sharing a first page-aligned
prompt chunk land on the SAME replica, so the per-replica radix tree
serves the group's shared pages at the single-replica hit rate.
"""
import numpy as np
import pytest

from repro.launch.router import (
    FleetRouter, prefix_replica, prefix_route_key,
)

PAGE = 32


# ---------------------------------------------------------------------------
# pure-host policy
# ---------------------------------------------------------------------------

def _prompt(prefix_id, tail):
    """Prompt with a one-page prefix determined by prefix_id + unique tail."""
    return np.concatenate([np.full(PAGE, 1000 + prefix_id, np.int32),
                           np.asarray(tail, np.int32)])


def test_route_key_is_first_page_chunk():
    a = _prompt(1, [7, 8, 9])
    b = _prompt(1, [4, 5])                    # same page-1 chunk, other tail
    c = _prompt(2, [7, 8, 9])
    assert prefix_route_key(a) == prefix_route_key(b)
    assert prefix_route_key(a) != prefix_route_key(c)
    # shorter-than-a-page prompts key on the whole prompt
    assert prefix_route_key([1, 2, 3]) == \
        prefix_route_key(np.asarray([1, 2, 3], np.int32))


def test_prefix_replica_deterministic_and_spread():
    """sha256-based assignment: stable across calls (and processes — the
    builtin hash is per-process salted and would not be), and it actually
    spreads distinct prefixes over replicas."""
    picks = [prefix_replica(_prompt(i, [0]), 4) for i in range(32)]
    assert picks == [prefix_replica(_prompt(i, [0]), 4) for i in range(32)]
    assert len(set(picks)) > 1                # not everything on one replica
    assert all(0 <= r < 4 for r in picks)


def test_router_spills_to_least_loaded():
    r = FleetRouter(3, policy="prefix", spill_threshold=4)
    p = _prompt(0, [1])
    home = prefix_replica(p, 3)
    loads = [0, 0, 0]
    assert r.pick(p, loads) == home and r.spills == 0
    loads[home] = 4                            # saturated: spill
    others = [i for i in range(3) if i != home]
    assert r.pick(p, loads) == min(others)     # least loaded, first wins
    assert r.spills == 1
    loads[home] = 3                            # below threshold: affinity
    assert r.pick(p, loads) == home and r.spills == 1


def test_router_random_policy_is_seeded():
    prompts = [_prompt(i, [0]) for i in range(16)]
    a = FleetRouter(4, policy="random", seed=3)
    b = FleetRouter(4, policy="random", seed=3)
    pa = [a.pick(p, [0] * 4) for p in prompts]
    assert pa == [b.pick(p, [0] * 4) for p in prompts]
    assert len(set(pa)) > 1


def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError, match="routing policy"):
        FleetRouter(2, policy="round-robin")


# ---------------------------------------------------------------------------
# EngineFleet over real engines (smoke model)
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")
import asyncio  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.router import EngineFleet  # noqa: E402
from repro.launch.server import AsyncServer, WorkItem, closed_loop  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.quant import linear as Q  # noqa: E402
from repro.runtime.batcher import ContinuousBatcher  # noqa: E402

KEY = jax.random.PRNGKey(11)


def _group_workload(cfg, n_groups=4, per_group=3, gen=4):
    """Group-major: `n_groups` families of `per_group` prompts, each family
    sharing a 2-page prefix + a unique tail."""
    work = []
    for g in range(n_groups):
        shared = jax.random.randint(jax.random.fold_in(KEY, g),
                                    (2 * PAGE,), 0, cfg.vocab)
        for j in range(per_group):
            tail = jax.random.randint(jax.random.fold_in(KEY, 100 + 10 * g + j),
                                      (8,), 0, cfg.vocab)
            work.append(WorkItem(prompt=jnp.concatenate([shared, tail]),
                                 max_new=gen))
    return work


def _run_fleet(cfg, params, work, *, routing, seed=0):
    runner = None
    bats = []
    for _ in range(2):
        bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=4, max_len=128,
                                n_pages=64, runner=runner)
        runner = runner or bat.runner          # replicas share the jit cache
        bats.append(bat)

    async def go():
        fleet = EngineFleet([AsyncServer(b) for b in bats], routing=routing,
                            spill_threshold=None, seed=seed)
        await fleet.start()
        mets = await closed_loop(fleet, work, rate=100.0, seed=seed)
        await fleet.shutdown(drain=True)
        return fleet, mets

    return asyncio.run(go())


def test_fleet_prefix_routing_deterministic_and_grouped():
    """Same seeded workload -> same replica assignment run over run, and
    every prefix-sharing group lands wholly on one replica (followers hit
    the leader's radix pages: per-fleet hit rate stays at the
    single-replica level instead of halving)."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    work = _group_workload(cfg)
    fleet1, mets1 = _run_fleet(cfg, params, work, routing="prefix")
    fleet2, _ = _run_fleet(cfg, params, work, routing="prefix")
    assert fleet1.assignments == fleet2.assignments     # deterministic
    per_group = 3
    for g in range(len(work) // per_group):
        grp = fleet1.assignments[g * per_group:(g + 1) * per_group]
        assert len(set(grp)) == 1, (g, grp)             # groups stay whole
    assert len(mets1) == len(work)
    ctr = fleet1.counters()
    assert ctr["completed"] == len(work)
    # every follower's 2 shared pages hit its group leader's radix entries
    assert ctr["fleet_affinity_hit_rate"] > 0.0
    assert ctr["fleet_prefix_hit_pages"] >= \
        2 * (per_group - 1) * (len(work) // per_group)
