"""Tensor-parallel serving: mesh factories + TP=N vs TP=1 token parity.

The serving meshes are plain-device-count friendly: the factory error
tests run at any device count, while the parity tests need >= 2 devices
and are driven in CI by the `sharded-serving` job under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (run locally the
same way). Parity is the tentpole acceptance bar: a TP=2 engine — params
sharded over "model", GQA page pools sharded on the KV-heads dim, block
table/scheduler replicated — must produce greedy tokens IDENTICAL to the
single-device engine for both fp and packed KV storage.
"""
import dataclasses

import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.mesh import make_host_mesh, make_serving_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.quant import linear as Q  # noqa: E402
from repro.runtime import paged_kv as PK  # noqa: E402
from repro.runtime.batcher import ContinuousBatcher, Request  # noqa: E402
from repro.runtime.model_runner import ModelRunner  # noqa: E402

NDEV = len(jax.devices())
KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# mesh factories (any device count)
# ---------------------------------------------------------------------------

def test_host_mesh_default_is_data_only():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == NDEV and mesh.shape["model"] == 1


def test_host_mesh_rejects_non_dividing_tp():
    """The old behaviour hard-coded model=1 and would silently absorb a
    misconfigured cell; now a tp that does not factor the device count
    fails loudly with the forcing hint."""
    with pytest.raises(ValueError, match="divide"):
        make_host_mesh(tp=NDEV + 1)
    with pytest.raises(ValueError, match=">= 1"):
        make_host_mesh(tp=0)


def test_serving_mesh_rejects_oversized_cell():
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh(tp=2 * NDEV, dp=2)
    with pytest.raises(ValueError, match=">= 1"):
        make_serving_mesh(tp=0)


def test_serving_mesh_is_a_subset_cell():
    """A (dp=1, tp=1) cell always builds, uses exactly one device, and
    leaves the rest of the host for sibling replicas."""
    mesh = make_serving_mesh(tp=1, dp=1)
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size == 1


# ---------------------------------------------------------------------------
# TP parity (>= 2 devices: the sharded-serving CI job)
# ---------------------------------------------------------------------------

def _parity_cfg():
    """Smoke config with fp32 compute. The smoke default computes in bf16,
    where TP resharding reassociates every contraction at ~0.4%-per-op
    granularity — percent-level logits drift that can legitimately flip a
    greedy argmax. Exact token parity is asserted where it is well-posed:
    fp32 compute, where the resharding-induced difference is ~1e-6 of the
    logits scale and an argmax flip would indicate a real sharding bug
    (mis-sharded pool, wrong constraint dim, dropped pages)."""
    return dataclasses.replace(configs.smoke_config("llama7b"),
                               compute_dtype=jnp.float32)


def _shared_prefix_workload(cfg, n_req=3, prefix_pages=2, gen=8):
    """Prompts sharing `prefix_pages` full pages + a unique tail: exercises
    radix sharing, chunked prefill, and decode appends under TP."""
    page = PK.PAGE_SIZE
    shared = jax.random.randint(KEY, (prefix_pages * page,), 0, cfg.vocab)
    prompts = []
    for i in range(n_req):
        tail = jax.random.randint(jax.random.fold_in(KEY, i),
                                  (5 + 3 * i,), 0, cfg.vocab)
        prompts.append(jnp.concatenate([shared, tail]))
    return prompts, gen


def _run_engine(cfg, params, storage, mesh, prompts, gen):
    qcfg = Q.FP if storage == "fp" else Q.QuantConfig(kv_cache="BBFP(6,3)")
    bat = ContinuousBatcher(cfg, params, qcfg, n_slots=4, max_len=128,
                            n_pages=40, kv_storage=storage, mesh=mesh)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    finished, _ = bat.run()
    assert len(finished) == len(prompts)
    return {r.rid: r.out_tokens for r in finished}, bat


@pytest.mark.skipif(NDEV < 2, reason="needs >= 2 devices (force with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("storage", ["fp", "packed"])
def test_tp2_decode_token_identical_to_tp1(storage):
    cfg = _parity_cfg()
    params = M.init(cfg, KEY)
    prompts, gen = _shared_prefix_workload(cfg)
    ref, _ = _run_engine(cfg, params, storage, None, prompts, gen)
    mesh = make_serving_mesh(tp=2)
    got, bat = _run_engine(cfg, params, storage, mesh, prompts, gen)
    assert got == ref, storage
    assert all(len(t) == gen for t in got.values())
    stats = bat.kv_stats()
    assert stats["kv_shards"] == 2


@pytest.mark.skipif(NDEV < 2, reason="needs >= 2 devices")
def test_tp2_pool_bytes_halve_per_shard():
    """GQA fp pools shard on the KV-heads dim: each device stores exactly
    half the global pool bytes (block table/pos are negligible and
    replicated — kv_bytes only counts the layer stores)."""
    cfg = _parity_cfg()
    params = M.init(cfg, KEY)
    prompts, gen = _shared_prefix_workload(cfg, n_req=1, gen=2)
    _, bat = _run_engine(cfg, params, "fp", make_serving_mesh(tp=2),
                         prompts, gen)
    stats = bat.kv_stats()
    assert stats["kv_store_bytes_per_shard"] * 2 == stats["kv_store_bytes"]
    _, solo = _run_engine(cfg, params, "fp", None, prompts, gen)
    assert solo.kv_stats()["kv_store_bytes_per_shard"] == \
        solo.kv_stats()["kv_store_bytes"]


@pytest.mark.skipif(NDEV < 2, reason="needs >= 2 devices")
def test_shared_tp_runner_across_facades():
    """Fleet replicas share one mesh-holding ModelRunner: the facade must
    adopt its mesh + sharded params (the runner sharded them, so identity
    against the original tree is via ``_params_src``)."""
    cfg = _parity_cfg()
    params = M.init(cfg, KEY)
    mesh = make_serving_mesh(tp=2)
    runner = ModelRunner(cfg, params, Q.FP, mesh=mesh)
    a = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=128,
                          runner=runner)
    b = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=128,
                          runner=runner)
    assert a.mesh is mesh and b.mesh is mesh
    assert a.params is runner.params and b.params is runner.params
    prompts, gen = _shared_prefix_workload(cfg, n_req=2, gen=4)
    for i, p in enumerate(prompts):
        a.submit(Request(rid=i, prompt=p, max_new=gen))
    fin, _ = a.run()
    assert len(fin) == 2 and all(len(r.out_tokens) == gen for r in fin)
