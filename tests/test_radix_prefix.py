"""Radix prefix tree + LRU retention (runtime/kv_manager.KVCacheManager).

Acceptance criteria of the radix upgrade over the exact-chain hash index:
  * longest-common-prefix matches BEAT the old exact-chain index on
    divergent-suffix workloads — in particular, a sequence that already
    RETIRED still serves its prefix pages (the old index evicted the entry
    the moment the pages were freed);
  * refcount / LRU / radix invariants hold under random admit / decode /
    retire / preempt schedules (hypothesis property sweep with the same
    deterministic fallback as test_bbfp_format.py / test_prefix_cache.py);
  * preempted-then-readmitted sequences decode token-identically to
    uninterrupted runs (fp AND packed GQA) — recompute plus whatever the
    LRU still holds is bit-exact because pages are whole BBFP quant blocks.
"""
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    def seeds(n):
        return settings(max_examples=n, deadline=None)(
            given(st.integers(0, 2**32 - 1)))
except ModuleNotFoundError:
    # bare containers (no network) fall back to a deterministic seed sweep
    def seeds(n):
        return pytest.mark.parametrize("seed", [13 * i + 5 for i in range(n)])

from repro.runtime import paged_kv as PK
from repro.runtime.kv_manager import KVCacheManager


def _chain_keys(tokens, page):
    """Exact-chain keys as the PRE-radix index derived them."""
    return [tuple(tokens[:(i + 1) * page]) for i in range(len(tokens) // page)]


# ---------------------------------------------------------------------------
# radix vs the old exact-chain index (host-side, no model)
# ---------------------------------------------------------------------------

def test_radix_beats_exact_chain_after_retirement():
    """The old index dropped a prefix the moment its pages hit refcount 0;
    the radix LRU keeps them resident until the pool actually reclaims
    them, so a follower arriving AFTER its prefix-mate retired still hits."""
    page, toks = 4, list(range(12))
    old = PK.PagedKVAllocator(n_pages=8, page=page, n_slots=2)
    pids = old.admit(0, 12, 12)
    old.register_prefix(_chain_keys(toks, page), pids)
    old.release(0)                              # retire -> index evicted
    assert old.match_prefix(_chain_keys(toks, page)) == []

    kv = KVCacheManager(n_pages=8, page=page, n_slots=2)
    pids = kv.admit(0, 12, 12)
    kv.register_tokens(toks, pids)
    kv.release(0)                               # retire -> pages CACHED
    assert kv.used_count == 0 and kv.cached_count == 3
    hit = kv.match_tokens(toks + [77, 78, 79, 80], max_pages=3)
    assert hit == pids                          # retired prefix still serves
    got = kv.admit(1, 16, 16, shared=hit)       # revival: cached -> active
    assert got[:3] == pids and kv.revivals == 3
    assert kv.cached_count == 0 and [kv.refcount[p] for p in pids] == [1, 1, 1]


def test_radix_longest_common_prefix_on_divergent_suffixes():
    """Divergent suffixes share exactly their common page-aligned head,
    and each divergent branch is indexed under its own radix path."""
    page = 4
    kv = KVCacheManager(n_pages=12, page=page, n_slots=3)
    a = [0, 1, 2, 3, 4, 5, 6, 7, 8, 9]          # pages (0..3), (4..7)
    b = [0, 1, 2, 3, 9, 9, 9, 9, 1, 2]          # diverges at page 1
    pa = kv.admit(0, len(a), len(a))
    kv.register_tokens(a, pa)
    hit_b = kv.match_tokens(b, max_pages=2)
    assert hit_b == pa[:1]                      # common head only
    pb = kv.admit(1, len(b), len(b), shared=hit_b)
    kv.register_tokens(b, pb)
    assert kv.refcount[pa[0]] == 2 and pb[0] == pa[0]
    # a third prompt following b's branch matches b's chain, not a's
    c = b[:8] + [5, 5, 5]
    assert kv.match_tokens(c, max_pages=2) == pb[:2]
    assert kv.radix_size == 3                   # shared head + 2 branches


def test_lru_evicts_leaf_up_and_only_when_needed():
    """Zero-refcount entries stay indexed until the pool must reclaim
    them; eviction takes the oldest CHILDLESS node so a cached chain is
    reclaimed leaf-up and a revived prefix is never left parentless."""
    page = 4
    kv = KVCacheManager(n_pages=4, page=page, n_slots=2)
    toks = list(range(12))
    pids = kv.admit(0, 12, 12)                  # 3 pages
    kv.register_tokens(toks, pids)
    kv.release(0)
    assert kv.cached_count == 3 and kv.evictions == 0
    # one free page left: a 2-page admission must evict exactly one cached
    # page, and it must be the LEAF of the chain (deepest page), keeping
    # the head of the chain matchable
    other = [50, 51, 52, 53, 54, 55]
    got = kv.admit(1, len(other), len(other))
    assert kv.evictions == 1
    assert kv.match_tokens(toks, max_pages=3) == pids[:2]   # leaf evicted
    assert pids[2] in got                        # the reclaimed page
    kv.release(1)
    # draining everything leaves free + cached partitioning the pool
    assert kv.used_count == 0
    assert len(kv.free) + kv.cached_count == kv.n_pages


def test_retention_disabled_frees_immediately():
    kv = KVCacheManager(n_pages=4, page=4, n_slots=1, retain=False)
    pids = kv.admit(0, 8, 8)
    kv.register_tokens(list(range(8)), pids)
    kv.release(0)
    assert kv.cached_count == 0 and kv.free_count == 4
    assert kv.match_tokens(list(range(8)), max_pages=2) == []
    assert kv.radix_size == 0


# ---------------------------------------------------------------------------
# property sweep: random admit/decode/retire/preempt schedules
# ---------------------------------------------------------------------------

@seeds(25)
def test_radix_invariants_random_schedules(seed):
    """Random schedules over a relaxed-capacity manager (the preemption
    configuration) keep the books consistent: refcounts match the slot
    page lists, free/cached/active partition the pool, every radix node
    points at a resident page, active pages pin their whole radix path,
    and draining every slot returns the pool to free+cached."""
    rng = random.Random(seed)
    page, n_slots = 4, 3
    n_pages = rng.randrange(6, 14)
    kv = KVCacheManager(n_pages, page, n_slots, strict_reserve=False)
    live = {}                                   # slot -> [tokens, rows, total]

    def walk(node, out):
        for child in node.children.values():
            out.append(child)
            walk(child, out)
        return out

    def check():
        held = [p for ps in kv.pages for p in ps]
        assert kv.used_count == len(set(held))
        assert sorted(set(kv.free)) == sorted(kv.free)
        assert not set(kv.free) & set(held)
        assert not set(kv.free) & set(kv._lru)
        assert not set(kv._lru) & set(held)
        for pid in range(n_pages):
            assert kv.refcount[pid] == held.count(pid)
            assert (pid in kv.free) == (kv.refcount[pid] == 0
                                        and pid not in kv._lru)
        nodes = walk(kv.root, [])
        assert len(nodes) == len(kv._node_of_page) == kv.radix_size
        for node in nodes:
            pid = node.page_id
            assert kv._node_of_page[pid] is node
            assert pid not in kv.free            # indexed => resident
            if kv.refcount[pid] >= 1:            # active pins its path
                anc = node.parent
                while anc is not kv.root:
                    assert kv.refcount[anc.page_id] >= 1, "stranded subtree"
                    anc = anc.parent
        for pid, node in kv._lru.items():
            assert kv.refcount[pid] == 0 and kv._node_of_page[pid] is node
        assert kv.allocatable == len(kv.free) + kv.cached_count
        assert kv.used_count + kv.cached_count + len(kv.free) == n_pages

    for _ in range(60):
        op = rng.randrange(4)
        free_slots = [s for s in range(n_slots) if s not in live]
        if op == 0 and free_slots:
            slot = rng.choice(free_slots)
            p_len = rng.randrange(1, 3 * page + 2)
            toks = [rng.randrange(3) for _ in range(p_len)]   # tiny alphabet
            max_new = rng.randrange(1, page + 2)
            total = p_len + max_new - 1
            hit = kv.match_tokens(toks, (p_len - 1) // page)
            if kv.can_admit_rows(p_len, total, hit):
                pids = kv.admit(slot, p_len, total, shared=hit)
                kv.register_tokens(toks, pids)
                live[slot] = [toks, p_len, total]
        elif op == 1 and live:                   # decode append
            slot = rng.choice(list(live))
            toks, rows, total = live[slot]
            if rows < total:
                try:
                    kv.ensure_row(slot, rows)
                    toks.append(rng.randrange(3))
                    live[slot][1] = rows + 1
                except PK.PoolExhausted:
                    pass                         # engine would preempt here
        elif op == 2 and live:                   # retire
            kv.release(rng.choice(list(live)))
            live = {s: v for s, v in live.items() if kv.pages[s]}
        elif op == 3 and live:                   # preempt (register+release)
            slot = rng.choice(list(live))
            toks, rows, _ = live[slot]
            kv.preempt_release(slot, toks[:rows])
            del live[slot]
        check()
    for slot in list(live):
        kv.release(slot)
        check()
    assert kv.used_count == 0
    assert len(kv.free) + kv.cached_count == n_pages


# ---------------------------------------------------------------------------
# end-to-end: retired-prefix reuse + preempt/readmit parity (real model)
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch.serve import generate  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.quant import linear as Q  # noqa: E402
from repro.runtime.batcher import ContinuousBatcher, Request  # noqa: E402

KEY = jax.random.PRNGKey(41)
PAGE = PK.PAGE_SIZE


def test_follower_after_retirement_still_hits_and_matches():
    """A follower submitted AFTER its prefix-mate fully retired still maps
    the shared pages out of the radix LRU (the pre-radix engine recomputed
    and re-stored them) and decodes token-identically to sequential."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    prefix = jax.random.randint(jax.random.fold_in(KEY, 1), (2 * PAGE,), 0,
                                cfg.vocab)
    lead = jnp.concatenate([prefix, jax.random.randint(
        jax.random.fold_in(KEY, 2), (5,), 0, cfg.vocab)])
    follow = jnp.concatenate([prefix, jax.random.randint(
        jax.random.fold_in(KEY, 3), (9,), 0, cfg.vocab)])
    gen = 4
    ref = generate(cfg, params, follow[None, :], Q.FP, gen_len=gen)[0].tolist()
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=128)
    bat.submit(Request(rid=0, prompt=lead, max_new=gen))
    finished, _ = bat.run()                     # leader fully retires...
    assert len(finished) == 1 and bat.alloc.used_count == 0
    assert bat.alloc.cached_count >= 2          # ...but its pages remain
    bat.submit(Request(rid=1, prompt=follow, max_new=gen))
    hits_before = bat.prefix_hit_pages
    finished, _ = bat.run()
    assert bat.prefix_hit_pages - hits_before == 2   # retired pages served
    assert bat.alloc.revivals >= 2
    got = next(r for r in finished if r.rid == 1).out_tokens[:gen]
    assert got == ref


@pytest.mark.parametrize("storage", ["fp", "packed"])
def test_preempted_then_readmitted_matches_uninterrupted(storage):
    """Force a mid-flight preemption of a specific request and compare with
    the identical engine run without the forced eviction: recompute-on-
    readmit (plus surviving radix pages) must be token-identical for fp
    AND packed GQA pools."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    prompts = [jax.random.randint(jax.random.fold_in(KEY, 10 + i), (n,), 0,
                                  cfg.vocab) for i, n in enumerate([36, 44])]
    gen = 8
    outs = {}
    for force in (False, True):
        bat = ContinuousBatcher(cfg, params, qcfg, n_slots=2, max_len=96,
                                kv_storage=storage, preempt=True)
        for i, p in enumerate(prompts):
            bat.submit(Request(rid=i, prompt=p, max_new=gen))
        ticks = 0
        while (bat.queue or any(r is not None for r in bat.slot_req)) \
                and ticks < 100:
            bat.step()
            ticks += 1
            if force and ticks == 3:
                victim = next(s for s, r in enumerate(bat.slot_req)
                              if r is not None and r.rid == 1)
                bat.sched.preempt(victim)
                bat._clear_slots([victim])
        assert len(bat.finished) == 2
        outs[force] = {r.rid: r.out_tokens for r in bat.finished}
        if force:
            assert bat.preemptions == 1 and bat.recomputed_tokens > 0
    assert outs[True] == outs[False], storage
