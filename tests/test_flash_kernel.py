"""Fused flash-attention + BBFP LUT softmax kernel vs oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_lut_attention import flash_lut_attention
from repro.quant import linear as Q

KEY = jax.random.PRNGKey(0)


def oracle(q, k, v, causal):
    s_len = q.shape[1]
    hd = q.shape[2]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((s_len, k.shape[1]), bool))[None] if causal else None
    probs = Q.qsoftmax(s, Q.PAPER, axis=-1, where=mask)
    return jnp.einsum("bqk,bkd->bqd", probs, v)


@pytest.mark.parametrize("s,hd,hd_v,causal", [
    (256, 64, 64, True),
    (256, 64, 64, False),
    (512, 128, 128, True),
    (256, 64, 32, True),     # v head dim != qk head dim (MLA-style)
])
def test_flash_lut_vs_oracle(s, hd, hd_v, causal):
    q = jax.random.normal(KEY, (2, s, hd), jnp.float32) * 0.4
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, s, hd), jnp.float32) * 0.4
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, s, hd_v), jnp.float32)
    out = flash_lut_attention(q, k, v, causal=causal, tq=128, tk=128)
    ref = oracle(q, k, v, causal)
    err = float(jnp.max(jnp.abs(out - ref)))
    scale = max(float(jnp.max(jnp.abs(ref))), 0.05)
    assert err / scale < 0.02, (err, scale)


def test_flash_lut_rows_normalised():
    q = jax.random.normal(KEY, (1, 256, 64)) * 0.3
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 256, 64)) * 0.3
    v = jnp.ones((1, 256, 64), jnp.float32)
    out = flash_lut_attention(q, k, v, causal=False)
    # with v == 1, each output row is the softmax row-sum == 1
    assert float(jnp.max(jnp.abs(out - 1.0))) < 0.02
