"""Fused flash-attention + BBFP LUT softmax kernel vs oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_lut_attention import (
    causal_live_tiles, flash_lut_attention,
)
from repro.quant import linear as Q

KEY = jax.random.PRNGKey(0)


def oracle(q, k, v, causal):
    s_len = q.shape[1]
    hd = q.shape[2]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((s_len, k.shape[1]), bool))[None] if causal else None
    probs = Q.qsoftmax(s, Q.PAPER, axis=-1, where=mask)
    return jnp.einsum("bqk,bkd->bqd", probs, v)


@pytest.mark.parametrize("s,hd,hd_v,causal", [
    (256, 64, 64, True),
    (256, 64, 64, False),
    (512, 128, 128, True),
    (256, 64, 32, True),     # v head dim != qk head dim (MLA-style)
])
def test_flash_lut_vs_oracle(s, hd, hd_v, causal):
    q = jax.random.normal(KEY, (2, s, hd), jnp.float32) * 0.4
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, s, hd), jnp.float32) * 0.4
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, s, hd_v), jnp.float32)
    out = flash_lut_attention(q, k, v, causal=causal, tq=128, tk=128)
    ref = oracle(q, k, v, causal)
    err = float(jnp.max(jnp.abs(out - ref)))
    scale = max(float(jnp.max(jnp.abs(ref))), 0.05)
    assert err / scale < 0.02, (err, scale)


def test_flash_lut_rows_normalised():
    q = jax.random.normal(KEY, (1, 256, 64)) * 0.3
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 256, 64)) * 0.3
    v = jnp.ones((1, 256, 64), jnp.float32)
    out = flash_lut_attention(q, k, v, causal=False)
    # with v == 1, each output row is the softmax row-sum == 1
    assert float(jnp.max(jnp.abs(out - 1.0))) < 0.02


def test_causal_tile_skip_flop_count():
    """§Perf C1: the skip's tile-FLOP accounting. causal_live_tiles is the
    exact number of (q,k) tile pairs the predicated kernel executes; for
    square causal attention it must be the lower-triangular-of-tiles count
    — strictly below the compute-everything grid and approaching half."""
    # 512x512 at 128-tiles: 4x4 tile grid, live = 1+2+3+4 = 10 of 16
    assert causal_live_tiles(512, 512, 128, 128) == 10
    # finer K tiles: per q tile qi, ki live while ki*64 <= qi*128 + 127
    # -> 2, 4, 6, 8 of 8 = 20 of 32
    assert causal_live_tiles(512, 512, 128, 64) == 20
    for sq, skv, tq, tk in [(512, 512, 128, 128), (1024, 1024, 128, 64),
                            (256, 512, 128, 128)]:
        total = (sq // tq) * (skv // tk)
        live = causal_live_tiles(sq, skv, tq, tk)
        assert live < total, (live, total)          # the skip saves tiles
        # never below the dense lower triangle (correctness floor)
        assert live * tq * tk >= sq * (sq + 1) // 2 if sq == skv else True
    # square grids approach the 2x FLOP win as tiles shrink
    assert causal_live_tiles(2048, 2048, 128, 32) / \
        ((2048 // 128) * (2048 // 32)) < 0.54


def test_causal_tile_skip_parity():
    """Skipping a fully-masked tile leaves the m/l/acc scratch bitwise
    unchanged vs computing-and-masking it: the kernel output with the skip
    (default) must match the compute-everything kernel (causal_skip
    disabled) exactly."""
    import repro.perf_flags as PF
    q = jax.random.normal(KEY, (2, 256, 64), jnp.float32) * 0.4
    k = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 256, 64),
                          jnp.float32) * 0.4
    v = jax.random.normal(jax.random.fold_in(KEY, 8), (2, 256, 64),
                          jnp.float32)
    out_skip = jax.device_get(flash_lut_attention(q, k, v, causal=True))
    old = PF._disabled
    try:
        PF._disabled = old | {"causal_skip"}
        jax.clear_caches()   # the flag is read at trace time — force retrace
        out_all = jax.device_get(flash_lut_attention(q, k, v, causal=True))
    finally:
        PF._disabled = old
        jax.clear_caches()
    assert (out_skip == out_all).all()
