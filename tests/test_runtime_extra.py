"""Extra runnability coverage: griffin ring-buffer wrap-around, elastic
restart onto a different device mesh (subprocess), multi-step generation."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as M
from repro.quant import linear as Q

KEY = jax.random.PRNGKey(0)


def test_griffin_ring_buffer_wraparound():
    """decode far past the attention window (ring buffer wraps) must match
    teacher-forced forward (which masks by the same window)."""
    cfg = configs.smoke_config("recurrentgemma_2b")   # window = 8
    params = M.init(cfg, KEY)
    total = 24                                        # 3x window
    tokens = jax.random.randint(KEY, (1, total), 0, cfg.vocab)
    mod = M.family_module(cfg)
    full_logits, _, _ = mod.forward(params, cfg, tokens, Q.FP)
    # prefill 4, then decode the rest one token at a time
    _, cache = M.prefill(params, cfg, tokens[:, :4], Q.FP, max_len=total)
    last = None
    for i in range(4, total):
        last, cache = M.decode_step(params, cfg, cache, tokens[:, i:i + 1], Q.FP)
    ref = full_logits[:, -1]
    err = float(jnp.max(jnp.abs(last - ref)))
    scale = max(float(jnp.max(jnp.abs(ref))), 1.0)
    assert err < 3e-2 * scale, (err, scale)


@pytest.mark.slow   # subprocess re-launch; minutes of XLA re-compilation
def test_elastic_restart_across_device_counts(tmp_path):
    """checkpoint written under 1 device restores under 4 fake devices with
    a sharded layout (the elastic-scaling path); loss continues identically."""
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro import configs
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch import sharding as S
from repro.models import model as M
from repro.quant import linear as Q

cfg = configs.get("llama7b").tiny_lm_config(vocab=64)
params = M.init(cfg, jax.random.PRNGKey(0))
save_checkpoint(r"{tmp_path}", 0, params)
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
pshapes = jax.eval_shape(lambda: params)
sh = S.param_shardings(pshapes, mesh, "serve")
step, restored = restore_checkpoint(r"{tmp_path}", params, shardings=sh)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
batch = dict(tokens=toks, labels=toks)
l0, _ = M.loss_fn(params, cfg, batch, Q.FP)
l1, _ = M.loss_fn(restored, cfg, batch, Q.FP)
# sharded matmuls reduce in a different order: small f32 tolerance
assert abs(float(l0) - float(l1)) < 5e-3, (float(l0), float(l1))
assert len(jax.devices()) == 4
print("ELASTIC_OK")
"""
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert "ELASTIC_OK" in res.stdout, res.stdout + res.stderr


def test_multistep_generation_all_decoder_archs():
    """8-token greedy generation stays finite and deterministic."""
    from repro.launch.serve import generate
    for arch in ["llama7b", "gemma3_4b", "mamba2_2_7b"]:
        cfg = configs.smoke_config(arch)
        params = M.init(cfg, KEY)
        prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
        t1 = generate(cfg, params, prompts, Q.PAPER, gen_len=8)
        t2 = generate(cfg, params, prompts, Q.PAPER, gen_len=8)
        assert t1.shape == (2, 8)
        assert bool(jnp.all(t1 == t2)), arch
