"""Extra runnability coverage: griffin ring-buffer wrap-around, elastic
restart onto a different device mesh (subprocess), multi-step generation."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as M
from repro.quant import linear as Q

KEY = jax.random.PRNGKey(0)


def test_griffin_ring_buffer_wraparound():
    """decode far past the attention window (ring buffer wraps) must match
    teacher-forced forward (which masks by the same window)."""
    cfg = configs.smoke_config("recurrentgemma_2b")   # window = 8
    params = M.init(cfg, KEY)
    total = 24                                        # 3x window
    tokens = jax.random.randint(KEY, (1, total), 0, cfg.vocab)
    mod = M.family_module(cfg)
    full_logits, _, _ = mod.forward(params, cfg, tokens, Q.FP)
    # prefill 4, then decode the rest one token at a time
    _, cache = M.prefill(params, cfg, tokens[:, :4], Q.FP, max_len=total)
    last = None
    for i in range(4, total):
        last, cache = M.decode_step(params, cfg, cache, tokens[:, i:i + 1], Q.FP)
    ref = full_logits[:, -1]
    err = float(jnp.max(jnp.abs(last - ref)))
    scale = max(float(jnp.max(jnp.abs(ref))), 1.0)
    assert err < 3e-2 * scale, (err, scale)


@pytest.mark.slow   # subprocess re-launch; XLA re-initialises from scratch
def test_elastic_restart_across_device_counts(tmp_path):
    """checkpoint written under 1 device restores under 4 fake devices with
    a sharded layout (the elastic-scaling path); loss continues identically.

    Two fixes over the original (which timed out in the dev container):
    the subprocess inherits the parent environment (a hand-stripped env
    hung jax's CPU client initialisation for minutes), and the mesh goes
    through launch.mesh._make_mesh (jax.sharding.AxisType only exists on
    newer jax). A hard 240s timeout converts any future hang into a crisp
    failure instead of eating the suite's budget."""
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro import configs
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch import sharding as S
from repro.launch.mesh import _make_mesh
from repro.models import model as M
from repro.quant import linear as Q

cfg = configs.get("llama7b").tiny_lm_config(vocab=64)
params = M.init(cfg, jax.random.PRNGKey(0))
save_checkpoint(r"{tmp_path}", 0, params)
mesh = _make_mesh((2, 2), ("data", "model"))
pshapes = jax.eval_shape(lambda: params)
sh = S.param_shardings(pshapes, mesh, "serve")
step, restored = restore_checkpoint(r"{tmp_path}", params, shardings=sh)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
batch = dict(tokens=toks, labels=toks)
l0, _ = M.loss_fn(params, cfg, batch, Q.FP)
l1, _ = M.loss_fn(restored, cfg, batch, Q.FP)
# sharded matmuls reduce in a different order: small f32 tolerance
assert abs(float(l0) - float(l1)) < 5e-3, (float(l0), float(l1))
assert len(jax.devices()) == 4
print("ELASTIC_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)        # the script sets its own device count
    try:
        res = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=240,
                             env=env, cwd=os.path.dirname(
                                 os.path.dirname(os.path.abspath(__file__))))
    except subprocess.TimeoutExpired as e:
        pytest.fail(f"elastic-restart subprocess exceeded the hard 240s "
                    f"timeout\nstdout: {e.stdout}\nstderr: {e.stderr}")
    assert "ELASTIC_OK" in res.stdout, res.stdout + res.stderr


def test_multistep_generation_all_decoder_archs():
    """8-token greedy generation stays finite and deterministic."""
    from repro.launch.serve import generate
    for arch in ["llama7b", "gemma3_4b", "mamba2_2_7b"]:
        cfg = configs.smoke_config(arch)
        params = M.init(cfg, KEY)
        prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
        t1 = generate(cfg, params, prompts, Q.PAPER, gen_len=8)
        t2 = generate(cfg, params, prompts, Q.PAPER, gen_len=8)
        assert t1.shape == (2, 8)
        assert bool(jnp.all(t1 == t2)), arch
