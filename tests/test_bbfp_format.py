"""BBFP/BFP format invariants (unit + hypothesis property tests)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # CI installs hypothesis via pyproject's [test] extra; bare containers
    # (no network) fall back to a deterministic sample sweep so the module
    # still collects and the invariants still run.
    class _Strategies:
        def integers(self, lo, hi):
            return [lo, hi, (lo + hi) // 2, 12345, 987654321]

        def sampled_from(self, xs):
            return list(xs)

    st = _Strategies()

    def settings(**_kw):
        return lambda f: f

    def given(*strategies):
        def deco(f):
            argnames = f.__code__.co_varnames[:f.__code__.co_argcount]
            cases = list(itertools.product(*[list(s)[:5] for s in strategies]))
            if len(argnames) == 1:
                cases = [c[0] for c in cases]
            return pytest.mark.parametrize(",".join(argnames), cases)(f)
        return deco

from repro.core import bbfp as B
from repro.core import error as E

FMTS = [B.BFP4, B.BFP6, B.BFP8, B.BBFP31, B.BBFP42, B.BBFP43, B.BBFP63, B.BBFP105]


def blocks(x, fmt):
    xb, _ = B._to_blocks(jnp.asarray(x, jnp.float32), fmt.block)
    return xb


# ---------- Table I exact values ----------

@pytest.mark.parametrize("fmt,expected", [
    (B.BFP8, 9.15625), (B.BFP6, 7.15625),
    (B.QuantFormat("bbfp", 8, 4), 10.15625), (B.BBFP63, 8.15625),
])
def test_equivalent_bit_width_table1(fmt, expected):
    assert abs(B.equivalent_bit_width(fmt, 32) - expected) < 1e-9


def test_memory_efficiency_ordering():
    # Table I: BFP6 (2.24x) > BFP8 (1.75x); BBFP slightly below same-m BFP
    assert B.memory_efficiency(B.BFP6) > B.memory_efficiency(B.BFP8)
    assert B.memory_efficiency(B.BBFP63) < B.memory_efficiency(B.BFP6)
    assert B.memory_efficiency(B.QuantFormat("bbfp", 8, 4)) < B.memory_efficiency(B.BFP8)


# ---------- quantiser invariants ----------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from(FMTS))
def test_roundtrip_error_bound(seed, fmt):
    """Elementwise error <= step/2, except the top sliver of the dynamic
    range (mantissa saturated at 2^m-1, inherent to (B)BFP) where it is
    <= one full step."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (4, 64)) * jnp.exp2(
        jax.random.randint(jax.random.fold_in(key, 1), (4, 64), -8, 8).astype(jnp.float32))
    y = B.fake_quant(x, fmt)
    qd = B.quantize_blocked(blocks(x, fmt), fmt)
    xb = blocks(x, fmt)
    e_s = B.shared_exponent(xb, fmt)
    e = B._exponent(xb)
    if fmt.kind == "bbfp":
        flag = (e > e_s[..., None]).astype(jnp.int32)
    else:
        flag = jnp.zeros_like(e)
    step = jnp.exp2((e_s[..., None] - fmt.mantissa + 1 + flag * fmt.shift).astype(jnp.float32))
    err = jnp.abs(blocks(x, fmt) - blocks(y, fmt))
    saturated = qd["mantissa"] >= 2**fmt.mantissa - 1
    bound = jnp.where(saturated, step, step * 0.5)
    assert bool(jnp.all(err <= bound * (1 + 1e-6) + 1e-12)), float(jnp.max(err / step))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_flag_semantics(seed):
    """flag=1 exactly for elements above the shared exponent (Eq. 4)."""
    fmt = B.BBFP42
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 64)) * 10
    qd, _ = B.quantize(x, fmt)
    xb = blocks(x, fmt)
    e = B._exponent(xb)
    e_s = qd["exp"]
    np.testing.assert_array_equal(np.asarray(qd["flag"]),
                                  np.asarray(e > e_s[..., None]).astype(np.int32))


def test_shared_exponent_eq9():
    """E_shared = max(E) - (m - o)."""
    x = jnp.asarray([[1.0, 2.0, 4.0, 1000.0] + [0.01] * 28])
    for fmt in [B.BBFP42, B.BBFP63]:
        e_s = B.shared_exponent(blocks(x, fmt), fmt)
        assert int(e_s[0, 0]) == 9 - fmt.shift  # floor(log2 1000)=9


def test_outlier_precision_equals_bfp():
    """BBFP gives outliers exactly plain-BFP precision (same step)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 32))
    x = x.at[:, 0].set(100.0)          # one outlier per block
    for m, o in [(4, 2), (6, 3)]:
        bb = B.QuantFormat("bbfp", m, o)
        bf = B.QuantFormat("bfp", m)
        ybb = B.fake_quant(x, bb)
        ybf = B.fake_quant(x, bf)
        np.testing.assert_allclose(np.asarray(ybb[:, 0]), np.asarray(ybf[:, 0]),
                                   rtol=0, atol=0)


def test_bulk_precision_gain():
    """non-outlier values gain (m-o) bits -> ~4x lower MSE for shift=2."""
    key = jax.random.PRNGKey(1)
    x = E.llm_activation_sample(key, (512, 512))
    mse_bb = float(E.empirical_mse(x, B.BBFP42))
    mse_bf = float(E.empirical_mse(x, B.QuantFormat("bfp", 4)))
    assert mse_bb < mse_bf / 2.5, (mse_bb, mse_bf)


def test_eq8_matches_empirical():
    """Eq. 8 closed form tracks empirical MSE within ~2x for all formats
    (BFP4 overestimates by 2.04x on this sample, hence the 2.2 bound)."""
    x = E.llm_activation_sample(jax.random.PRNGKey(2), (512, 512))
    for fmt in [B.BFP4, B.BFP6, B.BBFP31, B.BBFP42, B.BBFP63]:
        th = float(E.theoretical_variance(x, fmt))
        em = float(E.empirical_mse(x, fmt))
        assert 0.45 < th / em < 2.2, (fmt.name, th, em)


def test_fig3_shared_exponent_ordering():
    """max-3 >> max-1 > max-(m-o); max worst moderate (Fig. 3)."""
    x = E.llm_activation_sample(jax.random.PRNGKey(3), (512, 512))
    mses = {off: float(E.empirical_mse(
        x, B.QuantFormat("bbfp", 4, 2, exponent_offset=off)))
        for off in (-1, 0, 1, 2)}
    assert mses[0] < mses[1] < mses[-1]
    assert mses[0] < mses[2]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from(FMTS))
def test_int_repr_consistency(seed, fmt):
    """dequant(int_repr) == fake_quant exactly (the kernel arithmetic)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * 3
    q, scale = B.to_int_repr(x, fmt)
    y1 = q.astype(jnp.float32) * scale[..., None]
    y2 = blocks(B.fake_quant(x, fmt), fmt)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=0, atol=0)


def test_folded_max_int8_safety():
    assert B.folded_max(B.BBFP42) == 60      # int8-safe
    assert B.folded_max(B.BBFP31) == 28
    assert B.folded_max(B.BBFP63) == 504     # needs int16
    assert B.folded_max(B.INT8) == 127       # symmetric clip: int8-safe
    assert B.folded_max(B.BBFP105) == 32736  # still int16-safe


# ---------- oracle vs Pallas-kernel exponent parity ----------

def test_exponent_parity_oracle_vs_kernel_tile():
    """core.bbfp._exponent (frexp) and kernels.bbfp_matmul._exponent_tile
    (raw-bias bit trick) must clip identically on every edge class: zeros,
    subnormals, powers of two and their neighbours, 5-bit saturation at
    |x| >= 2^15, and inf/nan — otherwise the kernel silently picks a
    different shared exponent than the oracle it is validated against."""
    from repro.kernels.bbfp_matmul import _exponent_tile
    f32 = np.float32
    vals = [0.0, -0.0,
            1e-45, 5e-42, 1e-39,                 # subnormals -> _EXP_MIN
            np.finfo(f32).tiny,                  # 2^-126    -> clipped
            2.0**-17, 2.0**-16, 2.0**-15,        # around the exp floor
            0.5, 1.0, 1.5, 2.0, 3.0,
            float(np.nextafter(f32(2.0), f32(0))),   # just under a pow2
            2.0**14, float(np.nextafter(f32(2.0**15), f32(0))),
            2.0**15, 2.0**15 * 1.5, 2.0**16,     # 5-bit saturation
            3.4e38, float(np.inf), float(-np.inf), float(np.nan)]
    x = jnp.asarray(vals + [-v for v in vals], jnp.float32)
    e_oracle = np.asarray(B._exponent(x))
    e_kernel = np.asarray(_exponent_tile(x))
    np.testing.assert_array_equal(e_oracle, e_kernel)
    # pinned values on the named classes
    assert e_oracle[0] == B._EXP_MIN             # zero
    assert e_oracle[2] == B._EXP_MIN             # subnormal
    assert e_oracle[vals.index(2.0**15)] == B._EXP_MAX
    assert e_oracle[vals.index(float(np.inf))] == B._EXP_MAX
    assert e_oracle[vals.index(float(np.nan))] == B._EXP_MAX


# ---------- packed-weight round-trip (serving storage) ----------

def test_pack_unpack_roundtrip_all_formats():
    """pack_weight's docstring claim, verified bitwise for every registered
    format: unpack(pack(w)) == fake_quant(w.astype(bf16), axis=-2) EXACTLY,
    including the int baseline (float absmax scale, not a power of two) and
    an int16 folded-mantissa format like BBFP(6,3)."""
    w = jax.random.normal(jax.random.PRNGKey(6), (64, 16)) * 4
    w = w.at[3, :].set(50.0)                     # outliers drive the flags
    for fmt in B.FORMATS.values():
        if fmt.kind == "none":
            continue
        packed = B.pack_weight(w, fmt)
        want_dtype = jnp.int8 if B.folded_max(fmt) <= 127 else jnp.int16
        assert packed["q"].dtype == want_dtype, fmt.name
        assert packed["q"].shape == w.shape
        assert packed["scale"].shape == (64 // 32, 16)
        got = B.unpack_weight(packed)
        want = B.fake_quant(w.astype(jnp.bfloat16), fmt, axis=-2)
        assert got.dtype == want.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32),
                                      err_msg=fmt.name)


def test_pack_kv_roundtrip_and_idempotence():
    """KV page storage codes (serving): for every int8-codable format,
    (a) unpack(pack(x)) == fake_quant(x) EXACTLY for arbitrary x (packing IS
    the quantiser, just stored as sign|flag|mantissa bytes + exponent
    bytes), and (b) values already on the grid — the qkv_cache write path,
    including the bf16 cast of the cache — survive a pack/unpack round-trip
    bitwise, which is what makes packed pages numerically identical to fp
    pages end-to-end."""
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 3, 48)) * 6
    x = x.at[0, 0, 5].set(77.0)                  # outlier drives the flags
    for fmt in B.FORMATS.values():
        if fmt.kind == "none" or not B.kv_packable(fmt):
            continue
        packed = B.pack_kv(x, fmt)
        assert packed["q"].dtype == jnp.int8 and packed["q"].shape == x.shape
        assert packed["exp"].dtype == jnp.int8
        assert packed["exp"].shape == x.shape[:-1] + (2,)   # ceil(48/32)
        got = B.unpack_kv(packed, fmt, out_dtype=jnp.float32)
        want = B.fake_quant(x, fmt, axis=-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=fmt.name)
        # on-grid idempotence (bf16 store, as the cache writes it)
        grid = want.astype(jnp.bfloat16)
        back = B.unpack_kv(B.pack_kv(grid.astype(jnp.float32), fmt), fmt)
        np.testing.assert_array_equal(
            np.asarray(back, np.float32), np.asarray(grid, np.float32),
            err_msg=fmt.name)
    assert not B.kv_packable(B.BBFP105)          # needs 11+1 bits
    assert not B.kv_packable(B.INT8)             # float scale, not exponent


def test_zeros_and_signs():
    x = jnp.asarray([[0.0] * 32, [-1.5] * 32])
    for fmt in FMTS:
        y = B.fake_quant(x, fmt)
        assert float(jnp.max(jnp.abs(y[0]))) == 0.0
        assert bool(jnp.all(y[1] <= 0))


def test_parse_format():
    assert B.parse_format("BBFP(4,2)") == B.BBFP42
    assert B.parse_format("bbfp6_3").mantissa == 6
    assert B.parse_format("BFP6") == B.BFP6
    assert B.parse_format("int8").kind == "int"
    assert B.parse_format("none").kind == "none"


def test_matmul_ref_exactness():
    """bbfp_matmul_ref == dequantised operands matmul (fp32-exact ranges)."""
    a = jax.random.normal(jax.random.PRNGKey(4), (16, 96))
    b = jax.random.normal(jax.random.PRNGKey(5), (96, 8))
    for fmt in [B.BBFP42, B.BFP6]:
        got = B.bbfp_matmul_ref(a, b, fmt)
        want = B.fake_quant(a, fmt, axis=-1) @ B.fake_quant(b.T, fmt, axis=-1).T
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-5)
