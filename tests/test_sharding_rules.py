"""Sharding-rule unit tests (no devices needed: duck-typed mesh stub)."""
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as S


class StubMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class StubPodMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


M = StubMesh()


def test_attention_proj_train():
    assert S.param_spec("layers/attn/wq/w", (24, 2048, 2048), M, "train") \
        == P(None, "data", "model")
    assert S.param_spec("layers/attn/wo/w", (24, 2048, 2048), M, "train") \
        == P(None, "model", "data")


def test_mlp_train_and_serve():
    assert S.param_spec("layers/ffn/w_gate/w", (24, 2048, 8192), M, "train") \
        == P(None, "data", "model")
    assert S.param_spec("layers/ffn/w_gate/w", (24, 2048, 8192), M, "serve") \
        == P(None, None, "model")
    assert S.param_spec("layers/ffn/w_down/w", (24, 8192, 2048), M, "train") \
        == P(None, "model", "data")


def test_moe_expert_parallel():
    # (L, E, d, f): experts over model, d over data (train)
    assert S.param_spec("layers/ffn/w_gate", (48, 128, 2048, 768), M, "train") \
        == P(None, "model", "data", None)
    assert S.param_spec("layers/ffn/w_down", (48, 128, 768, 2048), M, "train") \
        == P(None, "model", None, "data")
    # serve: EP only
    assert S.param_spec("layers/ffn/w_gate", (48, 128, 2048, 768), M, "serve") \
        == P(None, "model", None, None)


def test_embeddings():
    assert S.param_spec("embed/w", (128256, 8192), M, "train") == P("model", "data")
    assert S.param_spec("lm_head/w", (8192, 128256), M, "train") == P("data", "model")


def test_indivisible_dims_fall_back_to_replicated():
    # 10 heads * 256 = 2560 / 16 = 160 OK; but a 6-head 384-dim whisper
    # projection (384x384): 384 % 16 == 0 -> sharded; 100x100 -> replicated
    assert S.param_spec("enc_layers/attn/wq/w", (100, 100), M, "train") == P(None, None)


def test_norm_scales_fsdp_fallback():
    # norm scales hit the fallback rule: large dim FSDP-sharded in train,
    # replicated in serve
    assert S.param_spec("layers/attn_norm/scale", (24, 2048), M, "train") \
        == P(None, "data")
    assert S.param_spec("layers/attn_norm/scale", (24, 2048), M, "serve") \
        == P(None, None)


def test_batch_spec():
    assert S.batch_spec((256, 4096), M) == P("data")
    assert S.batch_spec((256, 4096), StubPodMesh()) == P(("pod", "data"))
    assert S.batch_spec((1, 4096), M) == P()   # indivisible -> replicate


def test_cache_spec_batch_and_heads():
    # (L, B, T, KH, hd): B over data; KH=8 indivisible by 16 -> the cache is
    # SEQUENCE-parallel over model (avoids the per-layer cache reshard)
    spec = S.cache_spec("layers/k", (24, 128, 32768, 8, 128), M)
    assert spec[1] == "data" and spec[2] == "model"
    # divisible KV heads keep head sharding
    spec = S.cache_spec("layers/k", (24, 128, 32768, 16, 128), M)
    assert spec[1] == "data" and spec[3] == "model"


def test_cache_spec_long_context_seq_sharding():
    # batch=1: T spans both axes (2D sequence-parallel cache)
    spec = S.cache_spec("layers/k", (34, 1, 524288, 4, 256), M)
    assert spec[1] is None and spec[2] == ("data", "model")
