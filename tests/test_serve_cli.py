"""CLI guard matrix for repro.launch.serve.

Every invalid flag combination must be rejected at argparse time
(SystemExit from parser.error) with a message naming the conflict —
BEFORE any model work — so a bad launch fails in milliseconds, not after
a compile. Covers the pre-existing guards plus the new --serve family.
"""
import pytest

from repro.launch import serve


@pytest.mark.parametrize("argv,needle", [
    # packed KV pages live in the ContinuousBatcher's paged pool
    (["--kv-storage", "packed"], "requires --continuous"),
    # preemption is a property of the page pool
    (["--preempt"], "requires --continuous"),
    # the dense slab has no pages to evict
    (["--continuous", "--preempt", "--kv-layout", "dense"],
     "paged"),
    # packed storage IS a KV format; 'none' would store nothing
    (["--continuous", "--kv-storage", "packed", "--kv-quant", "none"],
     "needs a KV format"),
    # the demo drives the batcher synchronously; the server owns the loop
    (["--serve", "--preempt-demo"], "mutually exclusive"),
    # the closed-loop knobs are meaningless without the async front door
    (["--rate", "4"], "requires --serve"),
    (["--deadline-ms", "100"], "requires --serve"),
    (["--serve-slo", "interactive"], "requires --serve"),
    # the overlapped engine loop pipelines the paged engine
    (["--serve", "--kv-layout", "dense"], "paged"),
    # TP shards the serving engine's compiled shapes; the plain generate
    # path never builds them
    (["--tp", "2"], "requires --continuous"),
    (["--continuous", "--tp", "0"], ">= 1"),
    # replicas are AsyncServer engines behind the fleet router
    (["--replicas", "2"], "requires --serve"),
    (["--continuous", "--replicas", "2"], "requires --serve"),
    (["--serve", "--replicas", "0"], ">= 1"),
    # routing picks between fleet replicas; one engine has no choice
    (["--serve", "--routing", "prefix"], "requires --replicas"),
    (["--serve", "--replicas", "1", "--routing", "prefix"],
     "requires --replicas"),
    # chaos / supervision / shedding live in the AsyncServer engine
    # loop; the sync batcher path has no ticks to retry
    (["--chaos-seed", "7"], "requires --serve"),
    (["--continuous", "--chaos-kill-tick", "3"], "requires --serve"),
    (["--request-timeout-s", "5"], "requires --serve"),
    (["--continuous", "--shed-policy", "deadline"], "requires --serve"),
    # the two shedding knobs only make sense together
    (["--serve", "--shed-policy", "depth"], "requires --shed-depth"),
    (["--serve", "--shed-depth", "4"], "requires --shed-policy depth"),
    (["--serve", "--shed-policy", "deadline", "--shed-depth", "4"],
     "requires --shed-policy depth"),
    # the snapshot persists the radix tree + page pool
    (["--kv-snapshot", "/tmp/kv"], "requires --continuous"),
    (["--continuous", "--kv-snapshot", "/tmp/kv", "--kv-layout", "dense"],
     "paged"),
    (["--serve", "--kv-snapshot", "/tmp/kv", "--kv-layout", "dense"],
     "paged"),
    # packed4 nibble pages are only decoded inside the fused kernel; the
    # jnp fallback would dequantise them to bf16 every tick
    (["--continuous", "--kv-storage", "packed4"],
     "requires --paged-attn fused"),
    (["--continuous", "--kv-storage", "packed4", "--paged-attn", "unfused"],
     "requires --paged-attn fused"),
    (["--kv-storage", "packed4"], "requires --continuous"),
    # packed4 storage IS a KV format, same as packed
    (["--continuous", "--kv-storage", "packed4", "--paged-attn", "fused",
      "--kv-quant", "none"], "needs a KV format"),
    # the fused kernel decodes int8 BBFP pages — nothing to fuse in fp,
    # and the engine's compiled shapes only exist in continuous mode
    (["--paged-attn", "fused"], "requires --continuous"),
    (["--continuous", "--paged-attn", "fused"], "packed"),
    (["--continuous", "--paged-attn", "fused", "--kv-layout", "dense"],
     "paged"),
])
def test_invalid_flag_combos_rejected(argv, needle, capsys):
    with pytest.raises(SystemExit) as exc:
        serve.main(argv)
    assert exc.value.code == 2                 # argparse error, not a crash
    assert needle in capsys.readouterr().err


def test_serve_slo_choices_validated(capsys):
    with pytest.raises(SystemExit):
        serve.main(["--serve", "--serve-slo", "gold"])
    assert "invalid choice" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# fused + TP acceptance: the old "does not compose with --tp" rejection is
# GONE — page-dim sharding (flash-decoding sequence parallelism) runs the
# fused kernel per pool shard with a log-sum-exp merge.
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")
NDEV = len(jax.devices())

_FUSED_TP = ["--continuous", "--kv-storage", "packed", "--paged-attn",
             "fused", "--batch", "2", "--prompt-len", "8", "--gen", "2"]


def test_fused_with_tp_is_not_an_argparse_rejection(capsys):
    """fused + --tp 2 must get PAST argument validation: on a 1-device
    host the serving-mesh factory raises a ValueError naming the device
    shortfall (with the XLA forcing hint) — never argparse SystemExit(2).
    On >= 2 devices the engine serves end to end."""
    argv = _FUSED_TP + ["--tp", "2"]
    if NDEV >= 2:
        serve.main(argv)
        assert "served" in capsys.readouterr().out
    else:
        with pytest.raises(ValueError, match="devices"):
            serve.main(argv)


@pytest.mark.skipif(NDEV < 8, reason="needs >= 8 devices (the sharded-"
                    "serving CI job forces 8 host devices)")
@pytest.mark.parametrize("extra", [
    # smoke llama7b has 4 KV heads < tp=8: impossible under head-dim
    # sharding, fine under page-dim (no head divisibility requirement)
    ["--tp", "8"],
    # sub-byte nibble KV under TP — head-dim sharding never supported it
    ["--tp", "2", "--kv-storage", "packed4"],
])
def test_fused_tp_serves_end_to_end(extra, capsys):
    serve.main(_FUSED_TP + extra)
    assert "served" in capsys.readouterr().out
