"""Fault-tolerant serving: chaos injection, supervision, failover, warm
restart.

Acceptance criteria of the fault-tolerance PR:
  * a chaos-injected engine-tick failure is retried at the tick boundary
    and the recovered run is TOKEN-IDENTICAL to a fault-free one (the
    injection fires before any engine state mutates, so the retry is
    exact);
  * a POISONED request fails only its own stream — the server keeps
    ticking, every other stream completes, and the poisoned request's
    pages/slot are reclaimed (failure isolation);
  * a REPLICA KILL mid-decode fails the dead replica's streams over to a
    survivor: every request still completes, token-identical (greedy
    replay + skip-consume of already-delivered tokens);
  * a request exceeding its wall-clock TIMEOUT is cancelled out of the
    engine and the page pool returns to empty;
  * SHED batch-class requests terminate with an explicit outcome and
    never touch the engine;
  * a WARM-RESTARTED engine (radix/page snapshot through the checkpoint
    store) reports prefix hits on its FIRST admission round, with token
    parity against a cold run.

Every await is wrapped in a timeout so a livelocked loop fails the test
instead of hanging the suite.
"""
import asyncio
import tempfile
import types

import jax
import pytest

from repro import configs
from repro.launch.router import EngineFleet, prefix_replica
from repro.launch.server import (
    AsyncServer,
    RequestShed,
    RequestTimeout,
)
from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime.batcher import ContinuousBatcher, Request
from repro.runtime.faults import ChaosInjector, InjectedFailure, ReplicaKilled
from repro.runtime.model_runner import ModelRunner

KEY = jax.random.PRNGKey(11)
WAIT_S = 240.0


@pytest.fixture(scope="module")
def engine():
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    runner = ModelRunner(cfg, params, Q.FP, prefill_chunk=32,
                         prefill_slots=4)
    return cfg, params, runner


def _prompts(cfg, lens, salt=0):
    return [jax.random.randint(jax.random.fold_in(KEY, salt * 100 + i),
                               (n,), 0, cfg.vocab)
            for i, n in enumerate(lens)]


def _bat(engine, **kw):
    cfg, params, runner = engine
    return ContinuousBatcher(cfg, params, Q.FP, n_slots=4, max_len=128,
                             runner=runner, **kw)


def _ref_tokens(engine, prompts, gen):
    bat = _bat(engine)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    finished, _ = bat.run()
    return {r.rid: list(r.out_tokens) for r in finished}


async def _collect(stream):
    return [t async for t in stream]


# -- the chaos injector itself ----------------------------------------------

def test_chaos_injector_is_retry_exact():
    """A retried tick re-enters on_tick with the same key: the raise-once
    bookkeeping skips, the seeded draw does not re-roll, and the kill
    fires exactly once."""
    chaos = ChaosInjector(fail_ticks=(2,), kill_at_tick=5)
    chaos.on_tick(0)
    chaos.on_tick(1)
    with pytest.raises(InjectedFailure):
        chaos.on_tick(2)
    chaos.on_tick(2)                       # the retry of tick 2 is clean
    assert chaos.injected_failures == 1
    with pytest.raises(ReplicaKilled):
        chaos.on_tick(5)
    assert chaos.killed
    chaos.on_tick(6)                       # dead replicas don't re-kill
    # seeded per-tick draws are keyed by (seed, tick), not call order
    a = ChaosInjector(seed=3)
    b = ChaosInjector(seed=3)
    assert [a._draw(t) for t in range(8)] == [b._draw(t) for t in range(8)]


# -- tick retry --------------------------------------------------------------

def test_tick_retry_recovers_token_identical(engine):
    """Two injected tick failures: the supervised loop retries with
    backoff and every stream's greedy tokens equal the fault-free run."""
    cfg, _, _ = engine
    prompts = _prompts(cfg, [40, 50, 60, 70, 30, 44], salt=1)
    gen = 6
    ref = _ref_tokens(engine, prompts, gen)

    async def go():
        srv = AsyncServer(_bat(engine),
                          chaos=ChaosInjector(fail_ticks=(1, 3)),
                          backoff_s=0.005)
        await srv.start()
        streams = [srv.submit(p, gen) for p in prompts]
        outs = await asyncio.wait_for(
            asyncio.gather(*[_collect(s) for s in streams]), timeout=WAIT_S)
        await asyncio.wait_for(srv.shutdown(drain=True), timeout=WAIT_S)
        return srv, outs

    srv, outs = asyncio.run(go())
    assert {i: o for i, o in enumerate(outs)} == ref
    ctr = srv.counters()
    assert ctr["tick_failures"] == 2
    assert ctr["completed"] == 6 and ctr["failed"] == 0
    assert ctr["health"] in ("ok", "slow")   # survived: not dead


def test_fatal_after_retry_budget_marks_dead(engine):
    """More consecutive failures than the retry budget: the replica dies,
    open streams fail with the cause, submit rejects — but shutdown
    (drain=True) still joins cleanly."""
    cfg, _, _ = engine
    prompt = _prompts(cfg, [16], salt=2)[0]

    async def go():
        srv = AsyncServer(_bat(engine),
                          chaos=ChaosInjector(fail_ticks=(0, 0)),
                          tick_retries=0, backoff_s=0.005)
        await srv.start()
        stream = srv.submit(prompt, 8)
        with pytest.raises(InjectedFailure):
            await asyncio.wait_for(_collect(stream), timeout=WAIT_S)
        from repro.launch.server import ServerClosed
        with pytest.raises(ServerClosed):
            srv.submit(prompt, 8)
        await asyncio.wait_for(srv.shutdown(drain=True), timeout=WAIT_S)
        return srv

    srv = asyncio.run(go())
    assert srv.counters()["health"] == "dead"
    assert srv.counters()["failed"] == 1


# -- failure isolation -------------------------------------------------------

def test_poisoned_request_isolated(engine):
    """Poisoning request 1 fails ITS stream only: the other five complete
    token-identically and the poisoned request's pages are reclaimed."""
    cfg, _, _ = engine
    prompts = _prompts(cfg, [40, 50, 60, 70, 30, 44], salt=1)
    gen = 6
    ref = _ref_tokens(engine, prompts, gen)

    async def go():
        srv = AsyncServer(_bat(engine),
                          chaos=ChaosInjector(poison_rids=(1,)))
        await srv.start()
        streams = [srv.submit(p, gen) for p in prompts]
        outs = await asyncio.wait_for(
            asyncio.gather(*[_collect(s) for s in streams],
                           return_exceptions=True), timeout=WAIT_S)
        await asyncio.wait_for(srv.shutdown(drain=True), timeout=WAIT_S)
        return srv, outs

    srv, outs = asyncio.run(go())
    assert isinstance(outs[1], InjectedFailure)
    for i in (0, 2, 3, 4, 5):
        assert outs[i] == ref[i], i
    ctr = srv.counters()
    assert ctr["completed"] == 5 and ctr["failed"] == 1
    assert ctr["health"] in ("ok", "slow")
    assert srv.bat.kv.used_count == 0        # poisoned pages reclaimed
    mets = {m.rid: m for m in srv.metrics()}
    assert mets[1].outcome == "failed" and not mets[1].ok
    assert all(mets[i].outcome == "completed" for i in (0, 2, 3, 4, 5))


# -- replica kill + failover -------------------------------------------------

def test_replica_kill_fails_over_token_identical(engine):
    """Kill replica 0 mid-decode: its in-flight streams replay on the
    survivor (skip-consuming already-delivered tokens) and EVERY request
    completes with fault-free greedy tokens."""
    cfg, _, _ = engine
    # deterministic split: pick prompts whose prefix routes to each replica
    cands = _prompts(cfg, [40, 44, 48, 52, 56, 60, 64, 68, 36, 32], salt=4)
    to0 = [p for p in cands if prefix_replica(p, 2) == 0][:3]
    to1 = [p for p in cands if prefix_replica(p, 2) == 1][:3]
    assert len(to0) == 3 and len(to1) == 3, "salt no longer splits 3/3"
    prompts = to0 + to1
    gen = 8
    ref = _ref_tokens(engine, prompts, gen)

    async def go():
        srv0 = AsyncServer(_bat(engine),
                           chaos=ChaosInjector(kill_at_tick=3))
        srv1 = AsyncServer(_bat(engine))
        fleet = EngineFleet([srv0, srv1])
        await fleet.start()
        streams = [fleet.submit(p, gen) for p in prompts]
        outs = await asyncio.wait_for(
            asyncio.gather(*[_collect(s) for s in streams]), timeout=WAIT_S)
        await asyncio.wait_for(fleet.shutdown(drain=True), timeout=WAIT_S)
        return fleet, outs

    fleet, outs = asyncio.run(go())
    assert {i: o for i, o in enumerate(outs)} == ref
    ctr = fleet.counters()
    assert fleet.failovers >= 1, "the kill never forced a failover"
    assert ctr["health"] == ["dead", "ok"] or ctr["health"] == ["dead", "slow"]
    assert ctr["completed"] == len(prompts)
    # routing refuses the dead replica afterwards (even for an affinity
    # target that hashes to it)
    healthy = [h != "dead" for h in fleet.health()]
    assert all(fleet.router.pick(p, fleet._loads(), healthy) == 1
               for p in prompts)
    assert fleet.router.reroutes >= 1


# -- per-request timeouts ----------------------------------------------------

def test_request_timeout_frees_pages(engine):
    """An overdue request on a STALLED engine (chaos stall ticks) is
    cancelled: its stream fails with RequestTimeout and the page pool
    returns to empty."""
    cfg, _, _ = engine
    prompts = _prompts(cfg, [40, 30], salt=5)

    async def go():
        srv = AsyncServer(_bat(engine),
                          chaos=ChaosInjector(
                              stall_ticks=tuple(range(6, 200)),
                              stall_s=0.02))
        await srv.start()
        doomed = srv.submit(prompts[0], 80, timeout_s=0.25)
        fine = srv.submit(prompts[1], 4)
        done = await asyncio.wait_for(
            asyncio.gather(_collect(doomed), _collect(fine),
                           return_exceptions=True), timeout=WAIT_S)
        await asyncio.wait_for(srv.shutdown(drain=True), timeout=WAIT_S)
        return srv, done

    srv, (doomed_out, fine_out) = asyncio.run(go())
    assert isinstance(doomed_out, RequestTimeout)
    assert len(fine_out) == 4
    ctr = srv.counters()
    assert ctr["timeouts"] == 1 and ctr["completed"] == 1
    assert srv.bat.kv.used_count == 0        # slot retired, pages released
    mets = {m.rid: m for m in srv.metrics()}
    assert mets[0].outcome == "timeout" and not mets[0].ok


# -- load shedding -----------------------------------------------------------

def test_shed_requests_never_touch_engine(engine):
    """Depth-policy shedding: batch-class submissions past the depth
    threshold terminate with RequestShed at submit time — zero engine
    state touched — while interactive traffic is never shed."""
    cfg, _, _ = engine
    prompts = _prompts(cfg, [24, 28, 32, 36, 20], salt=6)
    gen = 4

    async def go():
        srv = AsyncServer(_bat(engine), shed_policy="depth", shed_depth=2)
        # submit BEFORE starting the loop: depth grows deterministically
        streams = [srv.submit(p, gen, slo="batch") for p in prompts[:4]]
        streams.append(srv.submit(prompts[4], gen, slo="interactive"))
        n_staged = len(srv._staged)
        await srv.start()
        outs = await asyncio.wait_for(
            asyncio.gather(*[_collect(s) for s in streams],
                           return_exceptions=True), timeout=WAIT_S)
        await asyncio.wait_for(srv.shutdown(drain=True), timeout=WAIT_S)
        return srv, n_staged, outs

    srv, n_staged, outs = asyncio.run(go())
    # batch #0, #1 admitted (depth 0, 1); #2, #3 shed (depth >= 2);
    # the interactive request rides through regardless of depth
    assert n_staged == 3                     # shed ones were never staged
    assert len(outs[0]) == gen and len(outs[1]) == gen
    assert isinstance(outs[2], RequestShed)
    assert isinstance(outs[3], RequestShed)
    assert len(outs[4]) == gen
    ctr = srv.counters()
    assert ctr["shed"] == 2 and ctr["completed"] == 3
    mets = {m.rid: m for m in srv.metrics()}
    assert mets[2].outcome == "shed" and mets[2].n_tokens == 0


def test_deadline_shed_projection():
    """The deadline policy sheds when projected first-token latency
    (depth x EWMA tick time) exceeds the budget — engine-free unit test
    over the decision function."""
    srv = AsyncServer(types.SimpleNamespace(
        paged=True, sched=types.SimpleNamespace(outstanding=lambda: 10)),
        shed_policy="deadline")
    srv._mon._mean, srv._mon._n = 0.1, 20    # 0.1 s/tick, warm monitor
    assert srv._should_shed("batch", deadline_s=0.5)       # 10*0.1 > 0.5
    assert not srv._should_shed("batch", deadline_s=2.0)   # fits
    assert not srv._should_shed("batch", deadline_s=None)  # no budget known
    assert not srv._should_shed("interactive", 0.1)        # never shed
    cold = AsyncServer(types.SimpleNamespace(
        paged=True, sched=types.SimpleNamespace(outstanding=lambda: 10)),
        shed_policy="deadline")
    assert not cold._should_shed("batch", 0.5)             # unwarmed monitor


# -- warm restart ------------------------------------------------------------

def test_warm_restart_prefix_hits_first_round(engine):
    """Snapshot a served engine's radix/page state, restore into a FRESH
    engine: the first admission round reports prefix hits (the cold run's
    follower-only hits are strictly exceeded) with token parity."""
    cfg, _, _ = engine
    prefix = jax.random.randint(jax.random.fold_in(KEY, 700), (64,),
                                0, cfg.vocab)
    prompts = [jax.numpy.concatenate(
        [prefix, jax.random.randint(jax.random.fold_in(KEY, 701 + i),
                                    (n,), 0, cfg.vocab)])
        for i, n in enumerate([5, 9, 13])]
    gen = 5
    ref = _ref_tokens(engine, prompts, gen)

    def run_server(bat):
        async def go():
            srv = AsyncServer(bat)
            await srv.start()
            streams = [srv.submit(p, gen) for p in prompts]
            outs = await asyncio.wait_for(
                asyncio.gather(*[_collect(s) for s in streams]),
                timeout=WAIT_S)
            await asyncio.wait_for(srv.shutdown(drain=True), timeout=WAIT_S)
            return outs
        return asyncio.run(go())

    donor = _bat(engine)
    assert run_server(donor) == [ref[i] for i in range(3)]
    snap_dir = tempfile.mkdtemp()
    n_snap = donor.snapshot_kv(snap_dir)
    assert n_snap > 0

    cold = _bat(engine)
    run_server(cold)
    cold_hits = cold.prefix_hit_pages        # followers only

    warm = _bat(engine)
    assert warm.restore_kv(snap_dir) == n_snap
    assert warm.kv.cached_count == n_snap and warm.kv.used_count == 0
    assert run_server(warm) == [ref[i] for i in range(3)]
    assert warm.prefix_hit_pages > cold_hits, \
        "restored radix state produced no extra first-round hits"
    assert warm.prefix_hit_rate > 0
