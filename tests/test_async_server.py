"""Async serving front door + overlapped engine loop.

Acceptance criteria of the PR-6 serving layer:
  * the overlapped tick (``step_overlapped``: host plans tick N+1 while
    tick N's decode is in flight, blocking only at the stream edge) is
    TOKEN-IDENTICAL to the synchronous ``step()`` path under greedy
    decode — including under preemption + recompute-on-readmit — and the
    ``overlapped_ticks`` counter proves real host/device overlap;
  * the ``AsyncServer`` streams every request's tokens as they decode,
    completes an OVERSUBSCRIBED workload (more streams than slots), and
    drains gracefully (zero open streams, end-of-stream sentinel on all);
  * SLO classes map onto the Scheduler's existing priority field, and
    ``deadline_s`` drives the goodput accounting (not scheduling);
  * shutdown rejects new submissions (``ServerClosed``) and a non-drained
    shutdown fails open streams loudly instead of hanging them.

Every await is wrapped in a timeout so a livelocked loop fails the test
instead of hanging the suite (the CI job also runs pytest-timeout).
"""
import asyncio
import types

import jax
import pytest

from repro import configs
from repro.launch.server import (
    SLO_PRIORITY,
    AsyncServer,
    ServerClosed,
    WorkItem,
    closed_loop,
    percentile_rows,
)
from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime.batcher import ContinuousBatcher, Request
from repro.runtime.model_runner import ModelRunner

KEY = jax.random.PRNGKey(0)
WAIT_S = 240.0                      # generous: tiny model, interpret-free


@pytest.fixture(scope="module")
def engine():
    """One model + ONE ModelRunner for the whole module: the cached jit
    decode/prefill objects compile once and every batcher façade below
    reuses them (same n_slots/pool shapes => no retracing)."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    runner = ModelRunner(cfg, params, Q.FP, prefill_chunk=32,
                         prefill_slots=4)
    return cfg, params, runner


def _prompts(cfg, lens, salt=0):
    return [jax.random.randint(jax.random.fold_in(KEY, salt * 100 + i),
                               (n,), 0, cfg.vocab)
            for i, n in enumerate(lens)]


def _bat(engine, **kw):
    cfg, params, runner = engine
    return ContinuousBatcher(cfg, params, Q.FP, n_slots=4, max_len=128,
                             runner=runner, **kw)


def _submit_all(bat, prompts, gen):
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    return bat


def _toks(finished):
    return {r.rid: list(r.out_tokens) for r in finished}


# -- overlapped loop parity --------------------------------------------------

def test_overlapped_loop_matches_sync_and_overlaps(engine):
    """6 requests onto 4 slots: the queued tail gives phase A real
    admission planning while decodes are in flight, so the overlap
    counter must tick — and greedy tokens must equal the sync path's."""
    cfg, _, _ = engine
    prompts = _prompts(cfg, [40, 50, 60, 70, 30, 44], salt=1)
    gen = 6
    ref = _toks(_submit_all(_bat(engine), prompts, gen).run()[0])
    ov = _submit_all(_bat(engine), prompts, gen)
    got = _toks(ov.run_overlapped()[0])
    assert got == ref
    assert ov.overlapped_ticks >= 1, "host never planned during a decode"
    assert len(got) == 6


def test_overlapped_loop_parity_under_preemption(engine):
    """The hard case: a starved pool preempts mid-flight (the victim's
    in-flight token must be DISCARDED via the slot-epoch check and
    regenerated after recompute-on-readmit), still token-identical to an
    unconstrained synchronous run."""
    cfg, _, _ = engine
    prompts = _prompts(cfg, [55, 58, 61], salt=2)
    gen = 10
    ref = _toks(_submit_all(_bat(engine), prompts, gen).run()[0])
    ov = _submit_all(_bat(engine, n_pages=6, preempt=True), prompts, gen)
    got = _toks(ov.run_overlapped()[0])
    assert ov.preemptions >= 1, "starved pool must have preempted"
    assert got == ref, "preemption under the overlapped loop diverged"
    assert all(len(t) == gen for t in got.values())


# -- the async front door ----------------------------------------------------

def test_server_streams_oversubscribed_workload(engine):
    """6 streams onto 4 slots, mixed SLO classes: every stream yields
    exactly max_new tokens (identical to the sync engine's), the server
    drains to zero open streams, and the metrics/counters add up."""
    cfg, _, _ = engine
    prompts = _prompts(cfg, [40, 50, 60, 70, 30, 44], salt=1)
    gen = 6
    ref = _toks(_submit_all(_bat(engine), prompts, gen).run()[0])
    slos = ["interactive", "standard", "batch"]

    async def go():
        srv = AsyncServer(_bat(engine))
        await srv.start()
        streams = [srv.submit(p, gen, slo=slos[i % 3], deadline_s=WAIT_S)
                   for i, p in enumerate(prompts)]

        async def collect(s):
            return [t async for t in s]

        outs = await asyncio.wait_for(
            asyncio.gather(*[collect(s) for s in streams]), timeout=WAIT_S)
        await asyncio.wait_for(srv.shutdown(drain=True), timeout=WAIT_S)
        return srv, outs

    srv, outs = asyncio.run(go())
    assert {i: o for i, o in enumerate(outs)} == ref
    ctr = srv.counters()
    assert ctr["completed"] == 6 and ctr["open_streams"] == 0
    mets = srv.metrics()
    assert len(mets) == 6
    assert all(m.n_tokens == gen and m.ttft_s > 0 and m.ok for m in mets)
    assert all(0 < m.ttft_s <= m.latency_s for m in mets)


def test_closed_loop_goodput_counts_deadline_misses(engine):
    """closed_loop drives seeded Poisson arrivals and percentile_rows
    computes goodput from the deadline 'ok' bit: an impossible deadline
    must count as completed-but-not-good."""
    cfg, _, _ = engine
    prompts = _prompts(cfg, [16, 20, 24, 28], salt=3)
    gen = 4
    work = [WorkItem(prompt=p, max_new=gen,
                     deadline_s=(1e-9 if i < 2 else WAIT_S))
            for i, p in enumerate(prompts)]

    async def go():
        srv = AsyncServer(_bat(engine))
        await srv.start()
        mets = await closed_loop(srv, work, rate=50.0, seed=7,
                                 timeout_s=WAIT_S)
        await asyncio.wait_for(srv.shutdown(drain=True), timeout=WAIT_S)
        return mets

    mets = asyncio.run(go())
    assert len(mets) == 4                      # all COMPLETED regardless
    pr = percentile_rows(mets)
    assert pr["of"] == 4 and pr["good"] == 2   # 2 missed their deadline
    assert pr["ttft_p50_us"] > 0 and pr["tpot_p50_us"] > 0
    assert pr["goodput_rps"] > 0


def test_submit_after_shutdown_rejected(engine):
    cfg, _, _ = engine
    prompt = _prompts(cfg, [8], salt=4)[0]

    async def go():
        srv = AsyncServer(_bat(engine))
        await srv.start()
        await asyncio.wait_for(srv.shutdown(drain=True), timeout=WAIT_S)
        with pytest.raises(ServerClosed):
            srv.submit(prompt, 4)

    asyncio.run(go())


def test_shutdown_without_drain_fails_open_streams(engine):
    cfg, _, _ = engine
    prompt = _prompts(cfg, [8], salt=5)[0]

    async def go():
        srv = AsyncServer(_bat(engine))
        await srv.start()
        stream = srv.submit(prompt, max_new=120)   # can't finish in time
        await asyncio.wait_for(srv.shutdown(drain=False), timeout=WAIT_S)
        with pytest.raises(ServerClosed):
            while True:                            # drain any tokens that
                await asyncio.wait_for(stream.__anext__(),  # did stream,
                                       timeout=WAIT_S)      # then the exc
        assert srv.counters()["open_streams"] == 0

    asyncio.run(go())


# -- SLO mapping (no engine needed: submit only stages) ----------------------

def test_slo_maps_to_scheduler_priority():
    srv = AsyncServer(types.SimpleNamespace(paged=True))
    assert srv.submit([1, 2], 4, slo="interactive").request.priority \
        == SLO_PRIORITY["interactive"]
    assert srv.submit([1, 2], 4, slo="batch").request.priority \
        == SLO_PRIORITY["batch"]
    assert srv.submit([1, 2], 4).request.priority == SLO_PRIORITY["standard"]
    # an explicit priority overrides the class mapping
    assert srv.submit([1, 2], 4, slo="batch", priority=9).request.priority == 9
    with pytest.raises(ValueError, match="SLO"):
        srv.submit([1, 2], 4, slo="gold")
    # server-assigned rids are unique and monotonic
    rids = [srv.submit([1], 1).request.rid for _ in range(3)]
    assert rids == sorted(rids) and len(set(rids)) == 3
