"""Segmented-LUT nonlinear unit (paper §IV.B, Table IV mechanisms)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bbfp as B
from repro.core import nonlinear as NL


def test_softmax_bbfp_close_to_fp():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 256)) * 3
    ref = jax.nn.softmax(x, -1)
    got = NL.softmax_lut(x, fmt=B.BBFP105)
    assert float(jnp.max(jnp.sum(jnp.abs(got - ref), -1))) < 0.08


def test_softmax_rows_sum_to_one_approx():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 512)) * 2
    got = NL.softmax_lut(x, fmt=B.BBFP105)
    np.testing.assert_allclose(np.asarray(jnp.sum(got, -1)), 1.0, atol=0.02)


def test_softmax_bbfp_beats_bfp_same_width():
    """Table IV direction: BBFP(10,5) LUT < BFP10 LUT error."""
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 2048)) * 2
    ref = jax.nn.softmax(x, -1)
    e_bb = float(jnp.mean(jnp.sum(jnp.abs(NL.softmax_lut(x, fmt=B.BBFP105) - ref), -1)))
    e_bf = float(jnp.mean(jnp.sum(jnp.abs(NL.softmax_lut(x, fmt=B.BFP10) - ref), -1)))
    assert e_bb < e_bf, (e_bb, e_bf)


def test_silu_gelu_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 512)) * 4
    s = NL.silu_bbfp(x)
    g = NL.gelu_bbfp(x)
    rs = float(jnp.linalg.norm(s - jax.nn.silu(x)) / jnp.linalg.norm(jax.nn.silu(x)))
    rg = float(jnp.linalg.norm(g - jax.nn.gelu(x)) / jnp.linalg.norm(jax.nn.gelu(x)))
    assert rs < 0.02 and rg < 0.05, (rs, rg)


def test_silu_outlier_robustness():
    """SiLU with outlier-heavy blocks: BBFP(10,5) degrades less than BFP10."""
    from repro.core import error as E
    x = E.llm_activation_sample(jax.random.PRNGKey(4), (256, 512),
                                outlier_frac=0.01, outlier_scale=30.0)
    ref = jax.nn.silu(x)
    eb = float(jnp.linalg.norm(NL.silu_lut(x, fmt=B.BBFP105) - ref))
    ef = float(jnp.linalg.norm(NL.silu_lut(x, fmt=B.BFP10) - ref))
    assert eb < ef, (eb, ef)


def test_lut_masked_softmax():
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64))
    mask = jnp.arange(64)[None, :] < 40
    got = NL.softmax_lut(x, fmt=B.BBFP105, where=mask)
    assert float(jnp.max(jnp.abs(got[:, 40:]))) == 0.0
    np.testing.assert_allclose(np.asarray(jnp.sum(got, -1)), 1.0, atol=0.02)


def test_lut_table_sizes():
    """7-bit address, table bank small enough for VMEM (paper: sub-tables
    selected by shared exponent)."""
    spec = NL.get_lut("exp", B.BBFP105)
    assert spec.table.shape[-1] == 2 ** NL.ADDRESS_BITS
    assert spec.table.nbytes <= 128 * 1024
    assert spec.n_subtables >= 8  # several non-trivial segments materialised


def test_exp_lut_monotone_on_negative_axis():
    # x descends (more negative) -> exp(x) must not increase (allow tiny
    # segment-boundary wiggles from bucket centring)
    x = -jnp.linspace(0.01, 10.0, 500)[None, :]
    y = NL.lut_apply(x, NL.get_lut("exp", B.BBFP105))[0]
    assert bool(jnp.all(jnp.diff(y) <= 1e-3))
