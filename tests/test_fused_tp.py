"""Sequence-parallel fused paged attention (page-dim sharding + merge).

The fused Pallas kernel composes with tensor parallelism via flash-decoding
sequence parallelism: each device owns a contiguous slice of the physical
page pool (``shard_paged_cache(..., shard_axis="pages")``), the kernel runs
per shard over LOCAL pages inside a shard_map, and the per-slot online-
softmax partials (m, l, acc) are combined with a log-sum-exp pmax/psum
merge (``paged_attention.merge_partials``).

Device-count-independent pieces — the merge math, block-table translation
round-trips, the heads-mode divisibility error, the MLA downgrade warning —
run everywhere. The TP=2 parity bars (decode + chunked prefill, packed AND
packed4, kv_heads < tp, preemption, cross-shard-count warm restart) need
>= 2 devices and are driven in CI by the `sharded-serving` job under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import dataclasses
import tempfile
import warnings

import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.core import bbfp as B  # noqa: E402
from repro.kernels import paged_attention as PA  # noqa: E402
from repro.launch.mesh import axis_size, make_serving_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.quant import linear as Q  # noqa: E402
from repro.runtime import paged_kv as PK  # noqa: E402
from repro.runtime.batcher import ContinuousBatcher, Request  # noqa: E402

NDEV = len(jax.devices())
KEY = jax.random.PRNGKey(11)

needs2 = pytest.mark.skipif(
    NDEV < 2, reason="needs >= 2 devices (force with "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _fp32(arch="llama7b", **over):
    cfg = dataclasses.replace(configs.smoke_config(arch),
                              compute_dtype=jnp.float32)
    return dataclasses.replace(cfg, **over) if over else cfg


# ---------------------------------------------------------------------------
# merge_partials: the log-sum-exp combine (any device count)
# ---------------------------------------------------------------------------

def test_merge_partials_matches_single_pass_softmax():
    """Hand-built partials: split a score row into two 'shards', run the
    online softmax per shard (exactly what the kernel's partials mode
    emits), and check the merged result against the one-pass softmax over
    the full row."""
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.standard_normal((3, 8)) * 4, jnp.float32)
    v = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    ref = (p @ v) / jnp.sum(p, axis=-1, keepdims=True)

    def partial(sc, vv):        # one shard's unnormalised flash state
        m = jnp.max(sc, axis=-1)
        e = jnp.exp(sc - m[:, None])
        return e @ vv, m, jnp.sum(e, axis=-1)

    accs, ms, ls = zip(partial(scores[:, :3], v[:3]),
                       partial(scores[:, 3:], v[3:]))
    merged = PA.merge_partials(jnp.stack(accs), jnp.stack(ms), jnp.stack(ls))
    assert np.abs(np.asarray(merged - ref)).max() < 1e-6


def test_merge_partials_dead_shard_and_dead_slot():
    """A shard that saw no live pages carries (m=-inf, l=0, acc=0) and must
    contribute NOTHING; a slot dead on EVERY shard (exp(-inf - -inf) would
    be NaN without the guard) must come out as zeros, matching the
    unsharded kernel's fully-masked rows."""
    acc = jnp.asarray([[[1.0, 2.0]], [[0.0, 0.0]]])      # (shard=2, slot=1, hd)
    m = jnp.asarray([[0.5], [-jnp.inf]])
    l = jnp.asarray([[2.0], [0.0]])
    out = PA.merge_partials(acc, m, l)
    assert np.allclose(np.asarray(out), [[0.5, 1.0]])    # acc / l, live shard only
    dead = PA.merge_partials(jnp.zeros_like(acc), jnp.full_like(m, -jnp.inf),
                             jnp.zeros_like(l))
    assert np.asarray(dead == 0).all() and np.isfinite(np.asarray(dead)).all()


def test_single_shard_merge_is_kernel_normalisation():
    """With one shard the merge reduces to acc/max(l,eps) exactly
    (scale = exp(0) = 1): partials mode + merge must be BITWISE the
    kernel's own normalised output."""
    fmt = B.parse_format("BBFP(6,3)")
    kh, hd, page, n_pages = 2, 64, 32, 8
    rng = np.random.default_rng(3)
    pool = lambda: {
        "q": jnp.asarray(rng.integers(-50, 50, (n_pages, page, kh, hd),
                                      dtype=np.int8)),
        "exp": jnp.asarray(rng.integers(-8, 0, (n_pages, page, kh, hd // 32),
                                        dtype=np.int8))}
    k_pool, v_pool = pool(), pool()
    q = jnp.asarray(rng.standard_normal((2, 1, kh, 1, hd)), jnp.float32)
    bt = jnp.asarray([[0, 1, 2, 8], [3, 4, 8, 8]], jnp.int32)
    pos = jnp.asarray([70, 40], jnp.int32)
    win = jnp.asarray(10**9, jnp.int32)
    ref = PA.paged_attention(q, k_pool, v_pool, bt, pos, win, fmt=fmt)
    acc, m, l = PA.paged_attention(q, k_pool, v_pool, bt, pos, win, fmt=fmt,
                                   partials=True)
    merged = PA.merge_partials(acc[None], m[None], l[None])
    assert (np.asarray(merged, np.float32) == np.asarray(ref)).all()


# ---------------------------------------------------------------------------
# block-table translation + pool sharding plumbing (any device count)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("storage,fmt_name", [
    ("fp", None), ("packed", "BBFP(6,3)"), ("packed4", "BBFP(2,1)")])
def test_translation_round_trips_every_pool_layout(storage, fmt_name):
    """global -> local -> global is the identity for OWNED pages in every
    storage layout's pool (the translation only consumes the table, so the
    layout enters via the pool's n_pages); non-local entries and the
    global sentinel both land on the LOCAL sentinel."""
    cfg = configs.smoke_config("llama7b")
    kv_fmt = B.parse_format(fmt_name) if fmt_name else None
    cache = PK.init_paged_cache(cfg, 2, 64, n_pages=8, storage=storage,
                                kv_fmt=kv_fmt)
    leaf = jax.tree.leaves(cache["layers"])[0]
    n_pages = leaf.shape[1]
    assert n_pages == 8
    shards, local_n = 2, n_pages // 2
    gids = jnp.arange(n_pages + 1)          # every page + the global sentinel
    for shard in range(shards):
        local = PK.translate_block_table(gids, local_n, shard)
        owned = (gids >= shard * local_n) & (gids < (shard + 1) * local_n)
        # non-owned (other shard's pages AND the sentinel) -> local sentinel
        assert (np.asarray(local[~np.asarray(owned)]) == local_n).all()
        back = PK.global_page_id(local[np.asarray(owned)], local_n, shard)
        assert (np.asarray(back) == np.asarray(gids[np.asarray(owned)])).all()
        # the local sentinel has no global preimage
        assert int(PK.global_page_id(jnp.asarray(local_n), local_n, shard)) == -1


def test_heads_mode_divisibility_error_points_at_page_mode():
    """The old silent replicate for kv_heads % tp != 0 is now a loud error
    whose message names the fix: shard_axis='pages' (the fused path)."""
    from jax.tree_util import DictKey
    leaf = jnp.zeros((2, 4, 32, 3, 16), jnp.int8)   # kv_heads=3, tp=2
    with pytest.raises(ValueError, match="pages"):
        PK._pool_spec((DictKey("k"), DictKey("q")), leaf, 2)
    # MLA latents (no k/v key in the path) still replicate silently
    from jax.sharding import PartitionSpec as P
    assert PK._pool_spec((DictKey("ckv"),), jnp.zeros((2, 4, 32, 7)), 2) == P()


def test_page_mode_requires_dividing_pool():
    mesh = make_serving_mesh(tp=NDEV)
    if axis_size(mesh, "model") < 2:
        pytest.skip("needs a model axis > 1")
    cfg = configs.smoke_config("llama7b")
    cache = PK.init_paged_cache(cfg, 2, 64, n_pages=NDEV + 1, storage="fp")
    with pytest.raises(ValueError, match="n_pages"):
        PK.shard_paged_cache(cache, mesh, shard_axis="pages")


def test_mla_fused_downgrade_warns_once_and_reports():
    """The MLA flag swallow is no longer silent: mla_apply warns ONCE per
    process and kv_stats surfaces paged_attn_effective='unfused'."""
    from repro.models import attention as A
    cfg = _fp32("deepseek_v2_lite_16b")
    assert cfg.mla is not None
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    bat = ContinuousBatcher(cfg, params, qcfg, n_slots=2, max_len=96,
                            n_pages=20, kv_storage="packed",
                            paged_attn="fused")
    stats = bat.kv_stats()
    assert stats["paged_attn"] == "fused"
    assert stats["paged_attn_effective"] == "unfused"
    bat.submit(Request(rid=0, prompt=jnp.asarray([1, 2, 3]), max_new=2))
    A._MLA_FUSED_WARNED = False             # re-arm the one-time flag
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        bat.run()
    msgs = [w for w in caught if "MLA" in str(w.message)]
    assert msgs, "fused-on-MLA downgrade must warn"
    # GQA fused engines report the fused path as effective
    gcfg = _fp32()
    gbat = ContinuousBatcher(gcfg, M.init(gcfg, KEY),
                             Q.QuantConfig(kv_cache="BBFP(6,3)"),
                             n_slots=2, max_len=96, n_pages=20,
                             kv_storage="packed", paged_attn="fused")
    assert gbat.kv_stats()["paged_attn_effective"] == "fused"
    assert gbat.kv_stats()["kv_shard_axis"] is None   # no mesh bound


# ---------------------------------------------------------------------------
# TP=2 parity: the sharded-serving CI bars (>= 2 devices)
# ---------------------------------------------------------------------------

def _prompts(cfg, lens, salt=0):
    return [jax.random.randint(jax.random.fold_in(KEY, salt + i), (n,), 0,
                               cfg.vocab) for i, n in enumerate(lens)]


def _run_fused(cfg, params, qcfg, prompts, gen, mesh, **kw):
    kw.setdefault("n_pages", 40)
    kw.setdefault("max_len", 96)
    bat = ContinuousBatcher(cfg, params, qcfg, n_slots=4,
                            paged_attn="fused", prefill_chunk=8,
                            mesh=mesh, **kw)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    fin, _ = bat.run()
    assert len(fin) == len(prompts)
    return {r.rid: r.out_tokens for r in fin}, bat


@needs2
@pytest.mark.parametrize("storage,fmt", [("packed", "BBFP(6,3)"),
                                         ("packed4", "BBFP(2,1)")])
def test_tp2_fused_token_identical_to_tp1(storage, fmt):
    """THE acceptance bar: a TP=2 fused engine — page pool split across
    devices, partials merged over the page axis — serves greedy tokens
    IDENTICAL to the unsharded fused engine at fp32, for int8 (packed)
    and sub-byte nibble (packed4) KV alike. Mixed prompt lengths with
    prefill_chunk=8 exercise chunked prefill (q_len=S) and decode
    (q_len=1) through the shard_map wrapper, with per-shard pool bytes
    summing to the global pool."""
    cfg = _fp32()
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache=fmt)
    prompts = _prompts(cfg, [5, 9, 30])
    ref, _ = _run_fused(cfg, params, qcfg, prompts, 6, None,
                        kv_storage=storage)
    got, bat = _run_fused(cfg, params, qcfg, prompts, 6,
                          make_serving_mesh(tp=2), kv_storage=storage)
    assert got == ref, storage
    stats = bat.kv_stats()
    assert stats["kv_shards"] == 2 and stats["kv_shard_axis"] == "pages"
    assert stats["kv_store_bytes_per_shard"] * 2 == stats["kv_store_bytes"]


@needs2
def test_tp2_fused_kv_heads_smaller_than_tp():
    """kv_heads=1 < tp=2 — impossible under head-dim sharding, previously
    rejected outright — completes end to end AND matches the unsharded
    fused engine's tokens (page-dim sharding has no head divisibility
    requirement)."""
    cfg = _fp32(n_kv_heads=1)
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    prompts = _prompts(cfg, [6, 21], salt=30)
    ref, _ = _run_fused(cfg, params, qcfg, prompts, 5, None,
                        kv_storage="packed")
    got, bat = _run_fused(cfg, params, qcfg, prompts, 5,
                          make_serving_mesh(tp=2), kv_storage="packed")
    assert got == ref
    assert all(len(t) == 5 for t in got.values())
    assert bat.kv_stats()["kv_shards"] == 2


@needs2
def test_tp2_fused_pool_rounds_up_to_shard_multiple():
    """An odd n_pages cannot split over 2 shards: the batcher rounds the
    pool UP (extra capacity, sentinel moves with it) instead of erroring."""
    cfg = _fp32()
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    bat = ContinuousBatcher(cfg, params, qcfg, n_slots=2, max_len=96,
                            n_pages=7, kv_storage="packed",
                            paged_attn="fused", mesh=make_serving_mesh(tp=2))
    assert bat.n_pages == 8
    leaf = jax.tree.leaves(bat.cache["layers"])[0]
    assert leaf.shape[1] == 8
    assert int(bat.cache["block_table"][0, 0]) == 8   # sentinel = n_pages


@needs2
def test_tp2_fused_preemption_token_identical():
    """Preemption + recompute-on-readmit under page-dim sharding: a
    starved TP=2 fused pool must preempt, recompute, and still emit the
    unconstrained engine's exact tokens."""
    cfg = _fp32()
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(6,3)")
    # 55-61-row prompts hold 2 pages each; +10 decode rows crosses into a
    # 3rd — 3 slots x 3 pages > the 6-page pool, forcing append-exhaustion
    # eviction + recompute-on-readmit
    prompts = _prompts(cfg, [55, 58, 61], salt=60)
    gen = 10
    ref, _ = _run_fused(cfg, params, qcfg, prompts, gen, None,
                        kv_storage="packed")
    got, bat = _run_fused(cfg, params, qcfg, prompts, gen,
                          make_serving_mesh(tp=2), kv_storage="packed",
                          n_pages=6, preempt=True)
    assert bat.sched.preemptions >= 1, "starved pool must have preempted"
    assert got == ref
    assert all(len(t) == gen for t in got.values())


@needs2
def test_snapshot_restores_across_shard_counts():
    """Warm restart is shard-count agnostic: snapshot a TP=2 page-sharded
    fused engine (snapshot gathers GLOBAL pages), restore into an
    UNSHARDED fused engine and into a fresh TP=2 engine — both re-serve
    the donor's prompts with first-round prefix hits and identical greedy
    tokens (bit-exact page bytes through the shard boundary)."""
    cfg = _fp32()
    params = M.init(cfg, KEY)
    qcfg = Q.QuantConfig(kv_cache="BBFP(2,1)")
    prefix = jax.random.randint(jax.random.fold_in(KEY, 70), (64,), 0,
                                cfg.vocab)
    prompts = [jnp.concatenate([prefix, t])
               for t in _prompts(cfg, [5, 9], salt=71)]
    kw = dict(kv_storage="packed4", max_len=128)
    ref, donor = _run_fused(cfg, params, qcfg, prompts, 4,
                            make_serving_mesh(tp=2), **kw)
    snap = tempfile.mkdtemp()
    n_snap = donor.snapshot_kv(snap)
    assert n_snap > 0
    for mesh in (None, make_serving_mesh(tp=2)):
        warm = ContinuousBatcher(cfg, params, qcfg, n_slots=4, n_pages=40,
                                 paged_attn="fused", prefill_chunk=8,
                                 mesh=mesh, **kw)
        assert warm.restore_kv(snap) == n_snap
        for i, p in enumerate(prompts):
            warm.submit(Request(rid=i, prompt=p, max_new=4))
        warm.run()
        assert {r.rid: r.out_tokens for r in warm.finished} == ref
        assert warm.prefix_hit_pages > 0, "restored pages must serve hits"
