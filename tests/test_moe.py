"""MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import common as C
from repro.models import ffn as F
from repro.quant import linear as Q

KEY = jax.random.PRNGKey(0)


def small_moe_cfg(cf=8.0, k=2, e=4):
    return C.ArchConfig(
        name="moetest", family="decoder", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=32, vocab=64, act="silu",
        moe=C.MoEConfig(n_experts=e, top_k=k, d_expert=32, capacity_factor=cf))


def dense_reference(params, x, cfg):
    """per-token explicit top-k mixture (no capacity) — ground truth."""
    m = cfg.moe
    t = x.shape[0]
    logits = x.astype(jnp.float32) @ params["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    out = jnp.zeros_like(x)
    for ti in range(t):
        acc = jnp.zeros((x.shape[-1],), x.dtype)
        for j in range(m.top_k):
            e = int(top_i[ti, j])
            h = jax.nn.silu(x[ti] @ params["w_gate"][e]) * (x[ti] @ params["w_up"][e])
            acc = acc + top_p[ti, j] * (h @ params["w_down"][e])
        out = out.at[ti].set(acc)
    return out


def test_moe_matches_dense_reference():
    cfg = small_moe_cfg(cf=16.0)  # capacity high enough: nothing dropped
    params = F.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 12, 32))
    got = F.moe_apply(params, x, cfg, Q.FP)[0]
    want = dense_reference(params, x[0], cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-3)


def test_moe_dropless_decode_never_drops():
    cfg = small_moe_cfg(cf=0.01)  # absurdly low capacity
    params = F.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (4, 1, 32))
    dropped = F.moe_apply(params, x, cfg, Q.FP, dropless=False)
    dropless = F.moe_apply(params, x, cfg, Q.FP, dropless=True)
    want = dense_reference(params, x.reshape(-1, 32), cfg).reshape(4, 1, 32)
    # dropless path == reference; capacity path lost tokens
    np.testing.assert_allclose(np.asarray(dropless), np.asarray(want),
                               rtol=2e-2, atol=2e-3)
    assert float(jnp.max(jnp.abs(dropped - want))) > 1e-3


def test_moe_aux_loss_balanced_vs_skewed():
    cfg = small_moe_cfg()
    params = F.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 64, 32))
    aux_rand = float(F.moe_aux_loss(params, x, cfg))
    # perfectly uniform router -> aux == n_experts * sum(1/E * 1/E * E) = 1
    params_flat = dict(params)
    params_flat["router"] = {"w": jnp.zeros_like(params["router"]["w"])}
    assert aux_rand >= 0.99  # aux >= 1 with equality iff perfectly balanced


def test_shared_experts_added():
    cfg = small_moe_cfg()
    cfg = C.ArchConfig(**{**cfg.__dict__,
                          "moe": C.MoEConfig(n_experts=4, top_k=2, d_expert=32,
                                             n_shared=1, d_shared=32,
                                             capacity_factor=8.0)})
    params = F.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 8, 32))
    with_shared = F.moe_apply(params, x, cfg, Q.FP)
    p2 = dict(params)
    p2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    without = F.moe_apply(p2, x, cfg, Q.FP)
    assert float(jnp.max(jnp.abs(with_shared - without))) > 1e-4
