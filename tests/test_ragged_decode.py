"""Ragged continuous batching: per-slot KV positions in the shared cache.

Covers the acceptance criteria of the ragged-decode rework:
  * ContinuousBatcher.step() issues exactly ONE jitted decode call per tick
    while slots sit at >= 3 distinct positions;
  * outputs are token-for-token identical to per-request sequential decode;
  * legacy scalar-pos caches still decode (broadcast compat);
  * sequence-synchronous families (mamba2/griffin) explicitly reject
    ragged position vectors.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch.serve import generate
from repro.models import model as M
from repro.quant import linear as Q
from repro.runtime.batcher import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(7)


def test_ragged_slots_single_decode_matches_sequential():
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    lens = [5, 9, 14]                      # three distinct prompt lengths
    prompts = [jax.random.randint(jax.random.fold_in(KEY, i), (n,), 0, cfg.vocab)
               for i, n in enumerate(lens)]
    gen = 6
    refs = [generate(cfg, params, p[None, :], Q.FP, gen_len=gen)[0].tolist()
            for p in prompts]

    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=3, max_len=64)
    calls = []
    inner = bat._decode
    bat._decode = lambda *a: (calls.append(1), inner(*a))[1]
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))

    ticks = 0
    while bat.queue or any(r is not None for r in bat.slot_req):
        before = len(calls)
        assert bat.step(), "live requests must decode"
        ticks += 1
        # exactly ONE jitted decode per tick, however ragged the batch is
        assert len(calls) == before + 1
        if ticks == 1:
            live = [bat.pos[s] for s, r in enumerate(bat.slot_req)
                    if r is not None]
            assert len(live) == 3 and len(set(live)) == 3, live
    assert bat.decode_calls == ticks == len(calls)

    got = {r.rid: r.out_tokens[:gen] for r in bat.finished}
    for i, ref in enumerate(refs):
        assert got[i] == ref, (i, got[i], ref)


def test_ragged_refill_keeps_one_call_per_tick():
    """more requests than slots: admissions refill freed slots mid-run,
    still one decode per tick."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=48)
    for i in range(5):
        bat.submit(Request(rid=i, prompt=jnp.arange(4 + 3 * i, dtype=jnp.int32),
                           max_new=3 + i % 2))
    finished, ticks = bat.run()
    assert len(finished) == 5
    assert bat.decode_calls == ticks
    assert all(len(r.out_tokens) == r.max_new for r in finished)


def test_ragged_moe_dense_layers_match_sequential():
    """MoE archs with leading dense layers keep a separate cache['dense'] —
    the prefill (paged chunk_prefill / dense _splice_dense) must write it
    too (regression: it was silently skipped)."""
    import dataclasses
    cfg = configs.smoke_config("deepseek_v2_lite_16b")   # first_dense=1, MLA
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init(cfg, KEY)
    prompts = [jax.random.randint(jax.random.fold_in(KEY, 10 + i), (6 + 3 * i,),
                                  0, cfg.vocab) for i in range(2)]
    gen = 4
    refs = [generate(cfg, params, p[None, :], Q.FP, gen_len=gen)[0].tolist()
            for p in prompts]
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=32)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    finished, _ = bat.run()
    got = {r.rid: r.out_tokens[:gen] for r in finished}
    for i, ref in enumerate(refs):
        assert got[i] == ref, (i, got[i], ref)


def test_submit_rejects_request_exceeding_capacity():
    """a decode write past max_len is a silent no-op, so an oversized
    request must be rejected up front, not silently diverge."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=1, max_len=14)
    with pytest.raises(ValueError, match="KV rows"):
        bat.submit(Request(rid=0, prompt=jnp.arange(10, dtype=jnp.int32),
                           max_new=8))
    bat.submit(Request(rid=1, prompt=jnp.arange(10, dtype=jnp.int32),
                       max_new=4))          # exactly fits
    finished, _ = bat.run()
    assert len(finished) == 1 and len(finished[0].out_tokens) == 4


def test_submit_boundary_exact_fit_is_admitted():
    """Off-by-one regression: the first token comes from prefill and the
    LAST generated token is never written back, so a request needs only
    prompt + max_new - 1 KV rows. A request that exactly fills max_len must
    be admitted (the old guard spuriously rejected it) and still match
    sequential decoding; one more token must be rejected."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    prompt = jax.random.randint(KEY, (10,), 0, cfg.vocab)
    gen = 5                                      # 10 + 5 - 1 == max_len
    ref = generate(cfg, params, prompt[None, :], Q.FP, gen_len=gen)[0].tolist()
    for layout in ("dense", "paged"):
        bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=1, max_len=14,
                                kv_layout=layout)
        bat.submit(Request(rid=0, prompt=prompt, max_new=gen))  # exact fit
        with pytest.raises(ValueError, match="KV rows"):
            bat.submit(Request(rid=1, prompt=prompt, max_new=gen + 1))
        finished, _ = bat.run()
        assert len(finished) == 1
        assert finished[0].out_tokens == ref, layout


def test_scalar_pos_cache_keeps_dense_fast_path():
    """a scalar cache['pos'] (dense same-length serving) decodes through the
    contiguous-write fast path and matches the ragged vector path."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 6), 0, cfg.vocab)
    _, cache = M.prefill(params, cfg, toks, Q.FP, max_len=16)
    assert cache["pos"].shape == (2,)              # ragged-native contract
    ref_logits, _ = M.decode_step(params, cfg, cache, toks[:, :1], Q.FP)
    cache["pos"] = jnp.asarray(6, jnp.int32)       # collapse to dense scalar
    logits, cache2 = M.decode_step(params, cfg, cache, toks[:, :1], Q.FP)
    assert jnp.ndim(cache2["pos"]) == 0            # scalar stays scalar
    assert int(cache2["pos"]) == 7
    assert float(jnp.max(jnp.abs(logits - ref_logits))) < 1e-5


def test_prefill_token_respects_budget_and_eos():
    """max_new and eos apply to the prefill-produced token too: such
    requests retire at admission without occupying a slot."""
    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, KEY)
    prompt = jnp.arange(6, dtype=jnp.int32)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=32)
    bat.submit(Request(rid=0, prompt=prompt, max_new=1))
    finished, _ = bat.run()
    assert len(finished) == 1 and len(finished[0].out_tokens) == 1
    assert bat.decode_calls == 0
    # same prompt, eos set to the token prefill will greedily emit
    eos = finished[0].out_tokens[0]
    bat2 = ContinuousBatcher(cfg, params, Q.FP, n_slots=2, max_len=32,
                             eos_id=eos)
    bat2.submit(Request(rid=1, prompt=prompt, max_new=8))
    finished2, _ = bat2.run()
    assert len(finished2) == 1 and finished2[0].out_tokens == [eos]
    assert bat2.decode_calls == 0


@pytest.mark.parametrize("arch", ["mamba2_2_7b", "recurrentgemma_2b"])
def test_sequence_synchronous_families_reject_ragged(arch):
    cfg = configs.smoke_config(arch)
    params = M.init(cfg, KEY)
    toks = jnp.zeros((2, 4), jnp.int32)
    _, cache = M.prefill(params, cfg, toks, Q.FP, max_len=16)
    cache["pos"] = jnp.asarray([4, 3], jnp.int32)  # ragged vector
    with pytest.raises(NotImplementedError, match="sequence-synchronous"):
        M.decode_step(params, cfg, cache, toks[:, :1], Q.FP)
