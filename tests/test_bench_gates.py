"""benchmarks/check_bench_gates.py against synthetic pass/fail fixtures.

The gate script is the ONLY place bench regressions are asserted (CI
runs it verbatim), so its logic gets direct unit coverage: every gate is
driven through a passing and a failing artifact, plus the schema-drift
backstop (an artifact matching NO gate must fail, not silently pass).

Stdlib-only on purpose — the script is loaded by file path, so this test
runs without jax or the repro package installed.
"""
import importlib.util
import json
import pathlib

import pytest

_PATH = (pathlib.Path(__file__).resolve().parent.parent
         / "benchmarks" / "check_bench_gates.py")
_spec = importlib.util.spec_from_file_location("check_bench_gates", _PATH)
cbg = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cbg)


def _artifact(tmp_path, name, rows):
    path = tmp_path / name
    path.write_text(json.dumps(
        {"commit": "deadbeef", "tiny": True,
         "rows": [{"name": n, "us_per_call": us, "derived": d}
                  for n, us, d in rows]}))
    return str(path)


# passing fixtures for every gate, keyed by the knob the tests flip
def _kernel_rows(ratio=0.53, dedup=50.0, hits=50.0, traces=1, steps=3,
                 chunks=9, preempted=1, completed=3, of=3, ratio4=0.27,
                 fused_match=True):
    return [
        ("serve/kv_bytes_per_slot_paged", 32768.0, "unit=bytes"),
        ("serve/kv_bytes_per_slot_packed", 32768.0 * ratio, "unit=bytes"),
        ("serve/kv_bytes_per_slot_packed4", 32768.0 * ratio4, "unit=bytes"),
        ("serve/decode_tick_fused", 100.0,
         f"slots=2 tokens_match={fused_match} vs=unfused_jnp compute=fp32"),
        ("serve/kv_bytes_logical_vs_physical", dedup, "unit=percent"),
        ("serve/prefix_hit_rate", hits, "unit=percent"),
        ("serve/batched_prefill_tick", 100.0,
         f"steps={steps} chunks={chunks} traces={traces}"),
        ("serve/preemption_recovery_tick", 100.0,
         f"preempted={preempted} completed={completed} of={of}"),
    ]


def _serving_rows(match=True, overlapped=7, completed=8, of=8, drained=True,
                  prefix=0.44, random=0.28, single=0.44, fleet_done=12,
                  fleet_of=12):
    return [
        ("serve/overlap_parity", 100.0,
         f"tokens_match={match} overlapped_ticks={overlapped} "
         f"host_idle_ticks=7 decode_calls=14"),
        ("serve/async_completion", 100.0,
         f"completed={completed} of={of} drained={drained} "
         f"overlapped_ticks=7 preemptions=0"),
        ("serve/fleet_affinity_hit_rate", prefix * 100.0,
         f"unit=% prefix={prefix:.4f} random={random:.4f} "
         f"single_replica={single:.4f} completed={fleet_done} "
         f"of={fleet_of} picks=3/9 spills=0"),
    ]


def _fault_rows(killed=1, failovers=3, fo_done=6, fo_of=6, fo_match=True,
                shed=4, expected_shed=4, shed_done=3, shed_of=7,
                shed_drained=True, snap=2, restored=2, warm_hits=6,
                cold_hits=4, hit_rate=0.67, wr_match=True):
    """The chaos-serving artifact: failover / shedding / warm restart."""
    return [
        ("serve/failover_recovery", 100.0,
         f"killed={killed} failovers={failovers} completed={fo_done} "
         f"of={fo_of} tokens_match={fo_match} reroutes=3"),
        ("serve/shed_overload", 100.0,
         f"shed={shed} expected_shed={expected_shed} completed={shed_done} "
         f"of={shed_of} served={shed_done} drained={shed_drained}"),
        ("serve/warm_restart", 100.0,
         f"snapshot_pages={snap} restored_pages={restored} "
         f"warm_hits={warm_hits} cold_hits={cold_hits} "
         f"hit_rate={hit_rate:.4f} tokens_match={wr_match}"),
    ]


def _tp_rows(match=True, shards=2, shard_bytes=32768, global_bytes=65536):
    """The sharded-serving artifact: only emitted with >= 2 devices."""
    return [
        ("serve/decode_tick_tp2", 100.0,
         f"tokens_match={match} kv_shards={shards} "
         f"shard_bytes={shard_bytes} global_bytes={global_bytes}"),
    ]


def _fused_tp_rows(match=True, shards=2, shard_bytes=174080,
                   global_bytes=348160, p4_shards=2, p4_shard=92160,
                   p4_global=184320):
    """The page-dim-sharded fused rows: only emitted with >= 2 devices."""
    return [
        ("serve/decode_tick_fused_tp2", 100.0,
         f"tokens_match={match} kv_shards={shards} "
         f"shard_bytes={shard_bytes} global_bytes={global_bytes} "
         f"compute=fp32 storage=packed"),
        ("serve/kv_bytes_per_shard_packed4_tp2", float(p4_shard),
         f"unit=bytes kv_shards={p4_shards} global_bytes={p4_global} "
         f"bits/elt=4.25"),
    ]


def test_all_gates_pass_on_good_artifacts(tmp_path, capsys):
    rc = cbg.main(["--json", _artifact(tmp_path, "k.json",
                                       _kernel_rows() + _fused_tp_rows()),
                   "--json", _artifact(tmp_path, "s.json",
                                       _serving_rows() + _fault_rows()
                                       + _tp_rows())])
    assert rc == 0
    assert "all bench gates passed" in capsys.readouterr().out


@pytest.mark.parametrize("rows,needle", [
    (_kernel_rows(ratio=0.60), "packed KV regressed"),
    (_kernel_rows(ratio4=0.35), "packed4 KV regressed"),
    (_kernel_rows(fused_match=False), "fused paged attention diverged"),
    (_kernel_rows(dedup=75.0), "not deduped"),
    (_kernel_rows(hits=30.0), "hit rate regressed"),
    (_kernel_rows(traces=2), "retraced"),
    (_kernel_rows(steps=9), "not batched"),
    (_kernel_rows(preempted=0), "never preempted"),
    (_kernel_rows(completed=2), "lost requests"),
    (_serving_rows(match=False), "diverged"),
    (_serving_rows(overlapped=0), "never overlapped"),
    (_serving_rows(completed=7), "streams lost"),
    (_serving_rows(drained=False), "drain left streams open"),
    (_serving_rows(prefix=0.28, random=0.28), "does not beat random"),
    (_serving_rows(prefix=0.30, random=0.28, single=0.44),
     "below the single-replica baseline"),
    (_serving_rows(fleet_done=11), "fleet lost streams"),
    (_fault_rows(killed=0), "kill did not land"),
    (_fault_rows(failovers=0), "never forced a failover"),
    (_fault_rows(fo_done=5), "failover lost requests"),
    (_fault_rows(fo_match=False), "diverged from the fault-free run"),
    (_fault_rows(shed=3), "shed count drifted"),
    (_fault_rows(shed_done=2), "non-shed streams lost"),
    (_fault_rows(shed_drained=False), "shed run left streams open"),
    (_fault_rows(snap=0, restored=0), "snapshot captured no pages"),
    (_fault_rows(restored=1), "restore dropped pages"),
    (_fault_rows(warm_hits=4), "no extra first-round hits"),
    (_fault_rows(wr_match=False), "diverged from the cold run"),
    (_tp_rows(match=False), "TP=2 decode diverged"),
    (_tp_rows(shards=1), "not sharded"),
    (_tp_rows(shard_bytes=65536), "not split across shards"),
    (_fused_tp_rows(match=False), "fused TP=2 decode diverged"),
    (_fused_tp_rows(shards=1), "fused page pool not sharded"),
    (_fused_tp_rows(shard_bytes=348160), "fused pool bytes not split"),
    (_fused_tp_rows(p4_shards=1), "packed4 pool not sharded"),
    (_fused_tp_rows(p4_shard=184320), "packed4 pool bytes not split"),
])
def test_each_gate_catches_its_regression(tmp_path, capsys, rows, needle):
    rc = cbg.main(["--json", _artifact(tmp_path, "bad.json", rows)])
    assert rc == 1
    out = capsys.readouterr()
    assert needle in out.out or needle in out.err


def test_one_failure_does_not_mask_others(tmp_path, capsys):
    """Gates keep running after a failure so one CI run reports ALL
    regressions, not just the first."""
    rows = _kernel_rows(ratio=0.60, hits=30.0, preempted=0)
    rc = cbg.main(["--json", _artifact(tmp_path, "bad.json", rows)])
    assert rc == 1
    err = capsys.readouterr().err
    for needle in ("packed KV regressed", "hit rate regressed",
                   "never preempted"):
        assert needle in err


def test_unrecognised_artifact_fails_loudly(tmp_path, capsys):
    """Schema drift (renamed rows) must fail the job, not skip gating."""
    rows = [("serve/renamed_row", 1.0, "k=v")]
    rc = cbg.main(["--json", _artifact(tmp_path, "drift.json", rows)])
    assert rc == 1
    assert "no gate matched" in capsys.readouterr().err


def test_gates_are_keyed_by_row_presence(tmp_path):
    """A file carrying only SOME gate families runs exactly those (the
    kernel and serving benches write separate artifacts)."""
    only_serving = _artifact(tmp_path, "s.json", _serving_rows())
    assert cbg.main(["--json", only_serving]) == 0
