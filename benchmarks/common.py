"""Shared benchmark machinery: the trained tiny LM (Table II/IV substrate),
timing helpers, result formatting.

WikiText2 + pretrained Llama/OPT are not available offline (DESIGN.md §7):
accuracy tables are reproduced as *orderings and relative deltas* on a tiny
LM trained in-repo on the synthetic bigram corpus, evaluated in true
held-out perplexity under each quantisation scheme.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import SyntheticLMDataset
from repro.launch import steps as ST
from repro.models import model as M
from repro.optim import adamw as O
from repro.quant import linear as Q

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
TINY_CKPT = os.path.join(RESULTS_DIR, "tiny_lm")
VOCAB = 512
SEQ = 128
TRAIN_STEPS = 250


def tiny_cfg():
    return configs.get("llama7b").tiny_lm_config(vocab=VOCAB)


def get_trained_tiny_lm():
    """Train once, cache in results/tiny_lm (restart-safe)."""
    cfg = tiny_cfg()
    template = jax.eval_shape(lambda k: M.init(cfg, k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
    if latest_step(TINY_CKPT) is not None:
        _, params = restore_checkpoint(TINY_CKPT, template)
        return cfg, params
    ocfg = O.AdamWConfig(lr=2e-3, total_steps=TRAIN_STEPS, warmup_steps=10)
    ds = SyntheticLMDataset(vocab=VOCAB, seq_len=SEQ, seed=0)
    state = ST.make_init_state(cfg, ocfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(ST.make_train_step(cfg, ocfg, Q.FP, remat=False))
    for s in range(TRAIN_STEPS):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s, 16).items()}
        state, metrics = step_fn(state, batch)
        if s % 50 == 0:
            print(f"  [tiny-lm] step {s} loss {float(metrics['loss']):.3f}",
                  flush=True)
    save_checkpoint(TINY_CKPT, TRAIN_STEPS, state["params"])
    return cfg, state["params"]


def emulate_llm_outliers(params, key=None, frac: float = 0.03,
                         scale: float = 25.0):
    """Function-preserving outlier injection (inverse SmoothQuant).

    Real LLMs exhibit heavy-tailed per-channel activation magnitudes
    (paper Fig. 1a); a 250-step tiny LM does not, which would make every
    block format look alike. We scale a random ~3% of channels in each
    pre-matmul RMSNorm gain by ~25x and divide the matching weight rows, so
    the fp model computes EXACTLY the same function (verified by test) but
    activations/weights now carry outlier blocks — the regime the paper's
    format targets. Documented in DESIGN.md §7 / EXPERIMENTS.md.
    """
    key = key if key is not None else jax.random.PRNGKey(123)
    p = jax.tree.map(lambda x: x, params)  # shallow-ish copy of the pytree

    def chan_scales(k, d):
        mask = jax.random.bernoulli(k, frac, (d,))
        mag = 1.0 + jax.random.uniform(jax.random.fold_in(k, 1), (d,)) * (scale - 1.0)
        return jnp.where(mask, mag, 1.0)

    layers = p["layers"]
    d = layers["attn_norm"]["scale"].shape[-1]
    n_l = layers["attn_norm"]["scale"].shape[0]
    k1, k2 = jax.random.split(key)
    s_attn = jax.vmap(lambda k: chan_scales(k, d))(jax.random.split(k1, n_l))
    s_ffn = jax.vmap(lambda k: chan_scales(k, d))(jax.random.split(k2, n_l))

    layers["attn_norm"]["scale"] = layers["attn_norm"]["scale"] * s_attn
    for w in ("wq", "wk", "wv"):
        layers["attn"][w]["w"] = layers["attn"][w]["w"] / s_attn[:, :, None]
    layers["ffn_norm"]["scale"] = layers["ffn_norm"]["scale"] * s_ffn
    for w in ("w_gate", "w_up"):
        layers["ffn"][w]["w"] = layers["ffn"][w]["w"] / s_ffn[:, :, None]
    p["layers"] = layers
    return p


def get_outlier_tiny_lm():
    cfg, params = get_trained_tiny_lm()
    return cfg, emulate_llm_outliers(params)


def eval_ppl(cfg, params, qcfg: Q.QuantConfig, n_batches: int = 8,
             seq: int = SEQ, batch: int = 16) -> float:
    """Held-out perplexity under a quantisation scheme (PTQ, no calibration)."""
    ds = SyntheticLMDataset(vocab=VOCAB, seq_len=seq, seed=0)
    loss_fn = jax.jit(lambda p, b: M.loss_fn(p, cfg, b, qcfg, remat=False)[0])
    tot = 0.0
    for i in range(n_batches):
        batch_d = {k: jnp.asarray(v) for k, v in
                   ds.batch(10_000 + i, batch).items()}  # held-out step range
        tot += float(loss_fn(params, batch_d))
    return float(np.exp(tot / n_batches))


def time_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"


def parse_derived(derived: str) -> dict:
    """Split a row's derived column into its ``k=v`` tokens (the format
    the CI gate script asserts on; free-text tokens are ignored)."""
    return dict(kv.split("=", 1) for kv in derived.split() if "=" in kv)


def write_bench_json(rows: list[str], path: str, tiny: bool):
    """Write bench rows as a BENCH_*.json artifact (one per commit; the
    perf-trajectory schema shared by every bench CLI). Row names may carry
    commas ("BBFP(4,2)") — fields split from the right."""
    import json

    recs = []
    for r in rows:
        name, us, derived = r.rsplit(",", 2)
        recs.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
    payload = {"commit": os.environ.get("GITHUB_SHA", ""),
               "tiny": tiny, "rows": recs}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
