"""Outlier-aware quantisation baseline (Olive/Oltron-style, simplified):
INT4 per block with one 'victim pair' — the largest-magnitude element of
each block keeps 8-bit precision. First-class in repro.quant (linear=
"outlier4"), no calibration, weights+activations — the paper's comparison
setting for Fig. 8."""
from repro.quant import linear as Q

OUTLIER_QCFG = Q.QuantConfig(linear="outlier4", nonlinear="none")
