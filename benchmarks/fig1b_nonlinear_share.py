"""Fig. 1(b): fraction of decoder runtime spent in nonlinear ops grows with
sequence length (the paper's motivation for accelerating the nonlinear
unit). Reproduced by timing the linear path (QKV/O + MLP GEMMs) vs the
nonlinear path (softmax + SiLU) of one decoder layer on this host across
sequence lengths."""
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us

D, H, FF = 512, 8, 2048


def run():
    key = jax.random.PRNGKey(0)
    wq = jax.random.normal(key, (D, D)) * 0.02
    wf = jax.random.normal(key, (D, FF)) * 0.02
    wo = jax.random.normal(key, (FF, D)) * 0.02

    out = []
    prev_share = 0.0
    monotone = True
    for s in [128, 512, 2048]:
        x = jax.random.normal(key, (1, s, D))
        scores = jax.random.normal(key, (1, H, s, s))
        hmid = jax.random.normal(key, (1, s, FF))

        lin = jax.jit(lambda x, h: ((x @ wq) @ (wq.T), (x @ wf), (h @ wo)))
        nl = jax.jit(lambda sc, h: (jax.nn.softmax(sc, -1), jax.nn.silu(h)))
        t_lin = time_us(lin, x, hmid)
        t_nl = time_us(nl, scores, hmid)
        share = t_nl / (t_nl + t_lin)
        out.append(row(f"fig1b/seq{s}", t_lin + t_nl,
                       f"nonlinear_share={share:.2%}"))
        monotone &= share >= prev_share - 0.02
        prev_share = share
    out.append(row("fig1b/share_grows_with_seq", 0.0, monotone))
    return out
