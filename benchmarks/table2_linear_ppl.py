"""Table II: perplexity of the quantised model (linear layers, weights +
activations, NO calibration) under each format.

Evaluated on the tiny LM with function-preserving LLM-outlier emulation
(benchmarks.common.emulate_llm_outliers — the Fig. 1a activation regime).
Paper claims reproduced as orderings:
  BBFP(3,1) better than BFP4;  BBFP(4,2) ~ BFP6 (within a few %);
  BBFP(6,3)/(6,4) ~ FP16.
"""
from benchmarks.common import get_outlier_tiny_lm, eval_ppl, row
from repro.quant import linear as Q

FORMATS = ["none", "BFP6", "BFP4", "BBFP(3,1)", "BBFP(4,2)", "BBFP(4,3)",
           "BBFP(6,3)", "BBFP(6,4)", "INT8"]


def run():
    cfg, params = get_outlier_tiny_lm()
    out = []
    ppl = {}
    for f in FORMATS:
        p = eval_ppl(cfg, params, Q.QuantConfig(linear=f, nonlinear="none"))
        ppl[f] = p
        out.append(row(f"table2/{'FP16' if f == 'none' else f}", 0.0,
                       f"ppl={p:.3f}"))
    checks = {
        "bbfp31_beats_bfp4": ppl["BBFP(3,1)"] < ppl["BFP4"],
        "bbfp42_close_to_bfp6": ppl["BBFP(4,2)"] < ppl["BFP6"] * 1.06,
        "bbfp63_close_to_fp16": ppl["BBFP(6,3)"] < ppl["none"] * 1.02,
        "bbfp64_close_to_fp16": ppl["BBFP(6,4)"] < ppl["none"] * 1.02,
    }
    for k, v in checks.items():
        out.append(row(f"table2/{k}", 0.0, v))
    return out
