"""Fig. 8: accuracy vs throughput at iso-PE-area.

Throughput proxy at equal area = 1 / area_model (PEs per mm^2) times the
int8-MXU eligibility of the folded format (BBFP<=4 rides the int8 path).
Accuracy = tiny-LM PPL (Table II machinery). Paper claims: BBFP(3,1) ~22%
better accuracy than an outlier-aware baseline at similar throughput, and
~40% higher throughput than BFP4 at similar accuracy.

The outlier-aware baseline (Olive/Oltron-style) is implemented as INT4 with
a per-block 1-outlier escape to 8 bits (victim-pair scheme, no calibration).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import get_outlier_tiny_lm, eval_ppl, row
from benchmarks.table3_area_proxy import area_model
from repro.core import bbfp as B
from repro.quant import linear as Q


def run():
    cfg, params = get_outlier_tiny_lm()
    out = []
    res = {}
    for name in ["BFP4", "BBFP(3,1)", "BBFP(3,2)", "BBFP(4,2)", "outlier-aware"]:
        if name == "outlier-aware":
            from benchmarks.outlier_baseline import OUTLIER_QCFG
            ppl = eval_ppl(cfg, params, OUTLIER_QCFG)
            area = area_model(B.parse_format("BBFP(3,1)"))  # 3-bit multipliers + escape
        else:
            ppl = eval_ppl(cfg, params, Q.QuantConfig(linear=name))
            area = area_model(B.parse_format(name))
        thr = 1000.0 / area
        res[name] = (ppl, thr)
        out.append(row(f"fig8/{name}", 0.0, f"ppl={ppl:.3f};thr_proxy={thr:.1f}"))
    ppl31, thr31 = res["BBFP(3,1)"]
    ppl4, thr4 = res["BFP4"]
    pplo, _ = res["outlier-aware"]
    out.append(row("fig8/bbfp31_thr_gain_vs_bfp4", 0.0,
                   f"{thr31/thr4-1:+.0%} (paper ~+40%)"))
    out.append(row("fig8/bbfp31_acc_vs_outlier_aware", 0.0,
                   f"ppl {ppl31:.3f} vs {pplo:.3f}"))
    return out
