"""Table IV: nonlinear layers quantised (linears kept fp).

Three layers of evidence (our 4-layer tiny LM cannot reproduce the paper's
3x-17x PPL blow-up magnitude; the mechanism is demonstrated at op level):

1. op-level (the unit itself, row-aligned like the paper's Align Exponent
   Unit): softmax total-variation + fraction of probabilities crushed to
   zero; SiLU relative error on outlier-heavy rows. BBFP(10,5) << BFP10.
2. end-to-end PPL with a SANE unit (exp domain bounded to [-32,0] so mask
   sentinels cannot poison the shared exponent — without this clamp BOTH
   formats lose ~24% PPL; finding documented in EXPERIMENTS.md).
3. end-to-end PPL with the clamp removed for BFP10-style alignment — the
   row-exponent-poisoning regime the paper's BFP10 baseline lives in.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import get_outlier_tiny_lm, eval_ppl, row
from repro.core import bbfp as B
from repro.core import error as E
from repro.core import nonlinear as NL
from repro.quant import linear as Q

EVAL_SEQ = 512


def _op_level():
    out = []
    s = jax.random.normal(jax.random.PRNGKey(0), (8, 2048)) * 2.0
    ref = jax.nn.softmax(s, -1)
    for name, fmt in [("BBFP(10,5)", B.BBFP105), ("BFP10", B.BFP10)]:
        p = NL.softmax_lut(s, fmt=fmt)
        l1 = float(jnp.mean(jnp.sum(jnp.abs(p - ref), -1)))
        nz = float(jnp.mean((p > 0).astype(jnp.float32)))
        out.append(row(f"table4/op_softmax_{name}", 0.0,
                       f"L1={l1:.4f};frac_probs_kept={nz:.3f}"))
    x = E.llm_activation_sample(jax.random.PRNGKey(1), (256, 2048),
                                outlier_frac=0.01, outlier_scale=40)
    r = jax.nn.silu(x)
    for name, fmt in [("BBFP(10,5)", B.BBFP105), ("BFP10", B.BFP10)]:
        y = NL.silu_lut(x, fmt=fmt)
        rel = float(jnp.linalg.norm((y - r).astype(jnp.float32).ravel() / 1e3)
                    / jnp.linalg.norm(r.astype(jnp.float32).ravel() / 1e3))
        out.append(row(f"table4/op_silu_{name}", 0.0, f"rel_err={rel:.4f}"))
    return out


def run():
    cfg, params = get_outlier_tiny_lm()
    out = _op_level()
    ppl = {}
    for name, qcfg in [("FP32", Q.QuantConfig()),
                       ("BBFP(10,5)", Q.QuantConfig(nonlinear="BBFP(10,5)")),
                       ("BFP10", Q.QuantConfig(nonlinear="BFP10"))]:
        p = eval_ppl(cfg, params, qcfg, n_batches=4, seq=EVAL_SEQ, batch=8)
        ppl[name] = p
        out.append(row(f"table4/e2e_{name}", 0.0, f"ppl={p:.3f}"))
    out.append(row("table4/e2e_bbfp_rel_increase", 0.0,
                   f"{ppl['BBFP(10,5)'] / ppl['FP32'] - 1:+.2%} (paper <=+8%)"))
    out.append(row("table4/e2e_bfp10_rel_increase", 0.0,
                   f"{ppl['BFP10'] / ppl['FP32'] - 1:+.2%} (paper: 3x-17x)"))
    # the poisoned-alignment regime (no domain clamp): both degrade hard,
    # BBFP less — the direction the paper reports, visible end-to-end
    orig = NL.EXP_LUT_RANGE
    try:
        NL.EXP_LUT_RANGE = -1e30
        for name in ["BBFP(10,5)", "BFP10"]:
            p = eval_ppl(cfg, params, Q.QuantConfig(nonlinear=name),
                         n_batches=3, seq=EVAL_SEQ, batch=8)
            out.append(row(f"table4/unbounded_{name}", 0.0, f"ppl={p:.3f}"))
    finally:
        NL.EXP_LUT_RANGE = orig
    return out
