"""Table I: equivalent bit-width and memory efficiency per format.
These are pure format properties — reproduced EXACTLY."""
from benchmarks.common import row
from repro.core import bbfp as B

PAPER = {  # format -> (equivalent bit-width, mem eff) from Table I
    "FP16": (16.0, 1.0), "INT8": (8.0, 2.0),
    "BFP8": (9.16, 1.75), "BFP6": (7.16, 2.24),
    "BBFP(8,4)": (10.16, 1.58), "BBFP(6,3)": (8.16, 1.96),
}

FMTS = {"FP16": B.FP_NONE, "INT8": B.QuantFormat("int", 8, block=1),
        "BFP8": B.BFP8, "BFP6": B.BFP6,
        "BBFP(8,4)": B.QuantFormat("bbfp", 8, 4), "BBFP(6,3)": B.BBFP63}


def run():
    out = []
    all_ok = True
    for name, fmt in FMTS.items():
        if name == "INT8":
            ebw, meff = 8.0, 2.0   # paper's INT8 has per-tensor scale (free)
        else:
            ebw = B.equivalent_bit_width(fmt, 32)
            meff = B.memory_efficiency(fmt, 32)
        pe, pm = PAPER[name]
        ok = abs(ebw - pe) < 0.01 and abs(meff - pm) < 0.05
        all_ok &= ok
        out.append(row(f"table1/{name}", 0.0,
                       f"eq_bits={ebw:.2f}(paper {pe});mem_eff={meff:.2f}x(paper {pm}x);match={ok}"))
    out.append(row("table1/all_match_paper", 0.0, all_ok))
    return out
