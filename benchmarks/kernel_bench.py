"""Kernel micro-benchmarks: Pallas bbfp_matmul (interpret mode on CPU) and
the jnp reference path, plus the roofline-relevant arithmetic intensity of
the BBFP GEMM (int8 path eligibility per format)."""
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us
from repro.core import bbfp as B
from repro.kernels import ops, ref


def run():
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512))
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
    out = []
    for fmt in ["BBFP(4,2)", "BBFP(6,3)", "BFP4", "INT8"]:
        us_ref = time_us(jax.jit(lambda a, b, f=fmt: ref.bbfp_matmul_ref(a, b, f)), a, b)
        f = B.parse_format(fmt)
        int8 = B.folded_max(f) <= 127
        out.append(row(f"kernel/matmul_ref_{fmt}", us_ref,
                       f"int8_mxu_path={int8}"))
    us_k = time_us(lambda: ops.bbfp_matmul(a, b, "BBFP(4,2)"))
    out.append(row("kernel/matmul_pallas_interpret_BBFP(4,2)", us_k,
                   "correctness path; TPU perf via BlockSpec tiling"))
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 4096))
    us_l = time_us(lambda: ops.lut_apply(x, "exp"))
    out.append(row("kernel/lut_exp_pallas_interpret", us_l, ""))
    return out
