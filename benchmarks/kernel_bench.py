"""Kernel micro-benchmarks: Pallas bbfp_matmul (interpret mode on CPU) and
the jnp reference path, plus the roofline-relevant arithmetic intensity of
the BBFP GEMM (int8 path eligibility per format) — and the SERVING path:
decode-tick latency and KV-bytes-per-slot of the continuous batcher under
both KV layouts (dense slab vs paged block allocator), so the perf
trajectory tracks the numbers that actually move serving throughput.

Standalone CLI for the CI bench-smoke job (tiny shapes, JSON artifact so the
perf trajectory accumulates one BENCH_*.json per commit):

  PYTHONPATH=src python -m benchmarks.kernel_bench --tiny --json BENCH_kernel.json
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us
from repro.core import bbfp as B
from repro.kernels import ops, ref


def run(tiny: bool = False):
    m, k, n = (64, 128, 64) if tiny else (256, 512, 256)
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    out = []
    for fmt in ["BBFP(4,2)", "BBFP(6,3)", "BFP4", "INT8"]:
        us_ref = time_us(jax.jit(lambda a, b, f=fmt: ref.bbfp_matmul_ref(a, b, f)), a, b)
        f = B.parse_format(fmt)
        int8 = B.folded_max(f) <= 127
        out.append(row(f"kernel/matmul_ref_{fmt}", us_ref,
                       f"int8_mxu_path={int8}"))
    us_k = time_us(lambda: ops.bbfp_matmul(a, b, "BBFP(4,2)"))
    out.append(row("kernel/matmul_pallas_interpret_BBFP(4,2)", us_k,
                   "correctness path; TPU perf via BlockSpec tiling"))
    # packed-operand serving GEMM: weight pre-packed offline (int8+scales),
    # consumed directly by the kernel vs the fp kernel's in-call weight
    # quantisation. Interpret-mode wall time is a correctness-path number;
    # the real win (~2x weight HBM reads, no weight-quant HLO) is structural
    # and shows in the derived column's bits accounting.
    fmtp = B.parse_format("BBFP(4,2)")
    packed = B.pack_weight(b, fmtp, cast_dtype=None)
    us_pk = time_us(lambda: ops.bbfp_matmul_packed(a, packed, "BBFP(4,2)"))
    q_bits = packed["q"].dtype.itemsize * 8
    stored = q_bits + 32 / B.DEFAULT_BLOCK    # int8 q + fp32 scale per 32
    out.append(row("gemm/packed_vs_fp_packed_BBFP(4,2)", us_pk,
                   f"weight_bits/elt={stored:.2f} stored+read "
                   f"(TableI ideal {B.equivalent_bit_width(fmtp):.2f})"))
    out.append(row("gemm/packed_vs_fp_fp_BBFP(4,2)", us_k,
                   "weight_bits/elt=16.00 (fp stream quantised in-kernel)"))
    # thin-row serving shape (decode GEMM: rows = batch): hits the kernel
    # via the tm=8 row tile instead of falling back to the jnp reference
    a_thin = jax.random.normal(jax.random.PRNGKey(5), (8, k))
    us_thin = time_us(lambda: ops.bbfp_matmul_packed(a_thin, packed, "BBFP(4,2)"))
    path = "tm=8 row tile" if 8 * n >= ops._MIN_KERNEL_ELEMS \
        else "jnp ref (below dispatch floor)"
    out.append(row("gemm/packed_decode_rows8_BBFP(4,2)", us_thin, path))
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 512) if tiny else (64, 4096))
    us_l = time_us(lambda: ops.lut_apply(x, "exp"))
    out.append(row("kernel/lut_exp_pallas_interpret", us_l, ""))
    out.extend(serving_rows(tiny=tiny))
    return out


def serving_rows(tiny: bool = False):
    """Serving-path metrics: steady-state decode-tick latency and KV bytes
    per slot for the continuous batcher, dense slab vs paged allocator.
    (Bytes rows reuse the value column; `derived` labels the unit.)"""
    from repro import configs
    from repro.models import model as M
    from repro.quant import linear as Q
    from repro.runtime.batcher import ContinuousBatcher, Request

    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, jax.random.PRNGKey(3))
    n_slots, max_len, gen = (2, 64, 14) if tiny else (4, 128, 24)
    timed_ticks = 4 if tiny else 8
    out = []
    # (row-suffix, kv_layout, kv_storage, qcfg): "packed" stores pages as
    # int8 codes + shared exponents in the BBFP(6,3) KV format. The paged-fp
    # baseline runs the SAME kv_cache quantisation so paged-vs-packed
    # isolates pure storage cost (same GEMMs, same fake-quant, identical
    # tokens); dense keeps Q.FP as the original unquantised reference.
    kvq = Q.QuantConfig(kv_cache="BBFP(6,3)")
    variants = [("dense", "dense", "fp", Q.FP),
                ("paged", "paged", "fp", kvq),
                ("packed", "paged", "packed", kvq)]
    for name, layout, storage, qcfg in variants:
        bat = ContinuousBatcher(cfg, params, qcfg, n_slots=n_slots,
                                max_len=max_len, kv_layout=layout,
                                kv_storage=storage)
        for i in range(n_slots):
            p_len = 5 + 7 * i                   # ragged mix
            prompt = jax.random.randint(jax.random.fold_in(
                jax.random.PRNGKey(4), i), (p_len,), 0, cfg.vocab)
            bat.submit(Request(rid=i, prompt=prompt, max_new=gen))
        bat.step()                              # admit + compile the decode
        stats = bat.kv_stats()                  # measured at full load
        t0 = time.perf_counter()
        n = 0
        while n < timed_ticks and bat.step():
            n += 1
        us_tick = (time.perf_counter() - t0) / max(n, 1) * 1e6
        # derived column must stay comma-free (the JSON writer rsplits rows)
        out.append(row(f"serve/decode_tick_{name}", us_tick,
                       f"slots={n_slots} max_len={max_len} one-jit-per-tick "
                       f"kvq={qcfg.kv_cache.replace(',', '_')}"))
        out.append(row(f"serve/kv_bytes_per_slot_{name}",
                       stats["kv_bytes_per_slot"], "unit=bytes (store/slots)"))
        if layout == "paged":
            out.append(row(f"serve/kv_bytes_in_use_{name}",
                           stats["kv_bytes_in_use"],
                           f"unit=bytes pages={stats['pages_in_use']}"
                           f"/{stats['pages_total']}"))
    out.extend(prefix_rows(cfg, params, tiny=tiny))
    return out


def prefix_rows(cfg, params, tiny: bool = False):
    """Prefix-cache + chunked-prefill metrics on a shared-system-prompt
    workload: 4 requests sharing a 64-token (2-page) prefix plus an 8-token
    unique suffix. Deterministic rows (the CI smoke gate reads them):
      * serve/prefix_hit_rate — percent of admitted prompt pages served
        from resident pages (here 6 of 12 = 50.0);
      * serve/kv_bytes_logical_vs_physical — physical/logical bytes at full
        load, as a percent; < 100 iff each shared page is stored exactly
        once (the no-sharing baseline is exactly 100);
      * serve/chunked_prefill_tick — mean wall time of one fixed-shape
        chunk-prefill step (the O(1)-compile replacement for the dense
        bucket ladder)."""
    from repro.quant import linear as Q
    from repro.runtime.batcher import ContinuousBatcher, Request

    n_req, gen = 4, (6 if tiny else 12)
    shared = jax.random.randint(jax.random.PRNGKey(6), (64,), 0, cfg.vocab)
    bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=n_req, max_len=128)
    # warm up the (single) chunk-prefill compilation with an unrelated
    # prompt that retires at admission, then zero the counters so the
    # timed rows are steady-state and the sharing stats cover only the
    # shared-prefix workload
    warm = jax.random.randint(jax.random.PRNGKey(8), (72,), 0, cfg.vocab)
    bat.submit(Request(rid=99, prompt=warm, max_new=1))
    bat.step()
    assert bat.alloc.used_count == 0 and bat.prefill_traces == 1
    bat.prefix_hit_pages = bat.prefix_miss_pages = bat.chunk_prefill_calls = 0
    for i in range(n_req):
        sfx = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(7), i),
                                 (8,), 0, cfg.vocab)
        bat.submit(Request(rid=i, prompt=jnp.concatenate([shared, sfx]),
                           max_new=gen))
    t0 = time.perf_counter()
    bat._admit()                                # admissions ONLY: no decode
    prefill_s = time.perf_counter() - t0        # (decode would add its own
    stats = bat.kv_stats()                      # first-call compile time)
    ratio = stats["kv_bytes_physical"] / max(stats["kv_bytes_logical"], 1)
    out = [row("serve/prefix_hit_rate", 100 * bat.prefix_hit_rate,
               f"unit=percent hit_pages={bat.prefix_hit_pages} "
               f"of={bat.prefix_hit_pages + bat.prefix_miss_pages}"),
           row("serve/kv_bytes_logical_vs_physical", 100 * ratio,
               f"unit=percent physical={stats['kv_bytes_physical']} "
               f"logical={stats['kv_bytes_logical']} "
               f"shared_pages={stats['pages_shared']}"),
           row("serve/chunked_prefill_tick",
               prefill_s / max(bat.chunk_prefill_calls, 1) * 1e6,
               f"chunks={bat.chunk_prefill_calls} traces={bat.prefill_traces} "
               f"(leader 3 + 3 hits x 1; no-sharing would be 12)")]
    bat.run()
    return out


def main(argv=None):
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shapes (seconds instead of minutes)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a BENCH_*.json artifact")
    args = ap.parse_args(argv)
    rows = run(tiny=args.tiny)
    print("name,us_per_call,derived")
    for r in rows:
        print(r, flush=True)
    if args.json:
        recs = []
        for r in rows:
            # format names carry commas ("BBFP(4,2)") — split from the right
            name, us, derived = r.rsplit(",", 2)
            recs.append({"name": name, "us_per_call": float(us), "derived": derived})
        payload = {"commit": os.environ.get("GITHUB_SHA", ""),
                   "tiny": args.tiny, "rows": recs}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
