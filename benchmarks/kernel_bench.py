"""Kernel micro-benchmarks: Pallas bbfp_matmul (interpret mode on CPU) and
the jnp reference path, plus the roofline-relevant arithmetic intensity of
the BBFP GEMM (int8 path eligibility per format) — and the SERVING path:
decode-tick latency and KV-bytes-per-slot of the continuous batcher under
both KV layouts (dense slab vs paged block allocator), so the perf
trajectory tracks the numbers that actually move serving throughput.

Standalone CLI for the CI bench-smoke job (tiny shapes, JSON artifact so the
perf trajectory accumulates one BENCH_*.json per commit):

  PYTHONPATH=src python -m benchmarks.kernel_bench --tiny --json BENCH_kernel.json
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us
from repro.core import bbfp as B
from repro.kernels import ops, ref


def run(tiny: bool = False):
    m, k, n = (64, 128, 64) if tiny else (256, 512, 256)
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    out = []
    for fmt in ["BBFP(4,2)", "BBFP(6,3)", "BFP4", "INT8"]:
        us_ref = time_us(jax.jit(lambda a, b, f=fmt: ref.bbfp_matmul_ref(a, b, f)), a, b)
        f = B.parse_format(fmt)
        int8 = B.folded_max(f) <= 127
        out.append(row(f"kernel/matmul_ref_{fmt}", us_ref,
                       f"int8_mxu_path={int8}"))
    us_k = time_us(lambda: ops.bbfp_matmul(a, b, "BBFP(4,2)"))
    out.append(row("kernel/matmul_pallas_interpret_BBFP(4,2)", us_k,
                   "correctness path; TPU perf via BlockSpec tiling"))
    # packed-operand serving GEMM: weight pre-packed offline (int8+scales),
    # consumed directly by the kernel vs the fp kernel's in-call weight
    # quantisation. Interpret-mode wall time is a correctness-path number;
    # the real win (~2x weight HBM reads, no weight-quant HLO) is structural
    # and shows in the derived column's bits accounting.
    fmtp = B.parse_format("BBFP(4,2)")
    packed = B.pack_weight(b, fmtp, cast_dtype=None)
    us_pk = time_us(lambda: ops.bbfp_matmul_packed(a, packed, "BBFP(4,2)"))
    q_bits = packed["q"].dtype.itemsize * 8
    stored = q_bits + 32 / B.DEFAULT_BLOCK    # int8 q + fp32 scale per 32
    out.append(row("gemm/packed_vs_fp_packed_BBFP(4,2)", us_pk,
                   f"weight_bits/elt={stored:.2f} stored+read "
                   f"(TableI ideal {B.equivalent_bit_width(fmtp):.2f})"))
    out.append(row("gemm/packed_vs_fp_fp_BBFP(4,2)", us_k,
                   "weight_bits/elt=16.00 (fp stream quantised in-kernel)"))
    # thin-row serving shape (decode GEMM: rows = batch): hits the kernel
    # via the tm=8 row tile instead of falling back to the jnp reference
    a_thin = jax.random.normal(jax.random.PRNGKey(5), (8, k))
    us_thin = time_us(lambda: ops.bbfp_matmul_packed(a_thin, packed, "BBFP(4,2)"))
    path = "tm=8 row tile" if 8 * n >= ops._MIN_KERNEL_ELEMS \
        else "jnp ref (below dispatch floor)"
    out.append(row("gemm/packed_decode_rows8_BBFP(4,2)", us_thin, path))
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 512) if tiny else (64, 4096))
    us_l = time_us(lambda: ops.lut_apply(x, "exp"))
    out.append(row("kernel/lut_exp_pallas_interpret", us_l, ""))
    out.extend(serving_rows(tiny=tiny))
    return out


def _serve_batcher(cfg, params, qcfg, prompts, max_new, **kw):
    """Shared serving-row setup: build a ContinuousBatcher and submit one
    request per prompt (the previously copy-pasted per-row boilerplate)."""
    from repro.runtime.batcher import ContinuousBatcher, Request

    bat = ContinuousBatcher(cfg, params, qcfg, **kw)
    for i, p in enumerate(prompts):
        bat.submit(Request(rid=i, prompt=p, max_new=max_new))
    return bat


def _prompts(cfg, lens, seed, prefix=None):
    """Deterministic prompts of the given lengths; `prefix` (an array) is
    shared verbatim by every prompt (prefix-cache workloads)."""
    ps = [jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(seed), i),
                             (n,), 0, cfg.vocab) for i, n in enumerate(lens)]
    if prefix is not None:
        ps = [jnp.concatenate([prefix, p]) for p in ps]
    return ps


def _timed_ticks(bat, n_ticks):
    """Mean wall time per decode tick over up to `n_ticks` steps (us)."""
    t0 = time.perf_counter()
    n = 0
    while n < n_ticks and bat.step():
        n += 1
    return (time.perf_counter() - t0) / max(n, 1) * 1e6


def serving_rows(tiny: bool = False):
    """Serving-path metrics: steady-state decode-tick latency and KV bytes
    per slot for the continuous batcher, dense slab vs paged allocator.
    (Bytes rows reuse the value column; `derived` labels the unit.)"""
    from repro import configs
    from repro.models import model as M
    from repro.quant import linear as Q

    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, jax.random.PRNGKey(3))
    n_slots, max_len, gen = (2, 64, 14) if tiny else (4, 128, 24)
    timed_ticks = 4 if tiny else 8
    out = []
    # (row-suffix, kv_layout, kv_storage, qcfg): "packed" stores pages as
    # int8 codes + shared exponents in the BBFP(6,3) KV format. The paged-fp
    # baseline runs the SAME kv_cache quantisation so paged-vs-packed
    # isolates pure storage cost (same GEMMs, same fake-quant, identical
    # tokens); dense keeps Q.FP as the original unquantised reference.
    kvq = Q.QuantConfig(kv_cache="BBFP(6,3)")
    variants = [("dense", "dense", "fp", Q.FP),
                ("paged", "paged", "fp", kvq),
                ("packed", "paged", "packed", kvq)]
    prompts = _prompts(cfg, [5 + 7 * i for i in range(n_slots)], seed=4)
    for name, layout, storage, qcfg in variants:
        bat = _serve_batcher(cfg, params, qcfg, prompts, gen,
                             n_slots=n_slots, max_len=max_len,
                             kv_layout=layout, kv_storage=storage)
        bat.step()                              # admit + compile the decode
        stats = bat.kv_stats()                  # measured at full load
        us_tick = _timed_ticks(bat, timed_ticks)
        # derived column must stay comma-free (the JSON writer rsplits rows)
        out.append(row(f"serve/decode_tick_{name}", us_tick,
                       f"slots={n_slots} max_len={max_len} one-jit-per-tick "
                       f"kvq={qcfg.kv_cache.replace(',', '_')}"))
        out.append(row(f"serve/kv_bytes_per_slot_{name}",
                       stats["kv_bytes_per_slot"], "unit=bytes (store/slots)"))
        if layout == "paged":
            out.append(row(f"serve/kv_bytes_in_use_{name}",
                           stats["kv_bytes_in_use"],
                           f"unit=bytes pages={stats['pages_in_use']}"
                           f"/{stats['pages_total']}"))
    out.extend(prefix_rows(cfg, params, tiny=tiny))
    out.extend(engine_rows(cfg, params, tiny=tiny))
    out.extend(fused_rows(cfg, params, n_slots, max_len, tiny=tiny))
    out.extend(fused_tp_rows(cfg, tiny=tiny))
    return out


def prefix_rows(cfg, params, tiny: bool = False):
    """Prefix-cache + chunked-prefill metrics on a shared-system-prompt
    workload: 4 requests sharing a 64-token (2-page) prefix plus an 8-token
    unique suffix. Deterministic rows (the CI smoke gate reads them):
      * serve/prefix_hit_rate — percent of admitted prompt pages served
        from resident pages (here 6 of 12 = 50.0);
      * serve/kv_bytes_logical_vs_physical — physical/logical bytes at full
        load, as a percent; < 100 iff each shared page is stored exactly
        once (the no-sharing baseline is exactly 100);
      * serve/chunked_prefill_tick — mean wall time of one fixed-shape
        chunk-prefill step (the O(1)-compile replacement for the dense
        bucket ladder)."""
    from repro.quant import linear as Q
    from repro.runtime.batcher import Request

    n_req, gen = 4, (6 if tiny else 12)
    shared = jax.random.randint(jax.random.PRNGKey(6), (64,), 0, cfg.vocab)
    bat = _serve_batcher(cfg, params, Q.FP, [], gen, n_slots=n_req,
                         max_len=128)
    # warm up the (single) chunk-prefill compilation with an unrelated
    # prompt that retires at admission, then zero the counters so the
    # timed rows are steady-state and the sharing stats cover only the
    # shared-prefix workload
    warm = jax.random.randint(jax.random.PRNGKey(8), (72,), 0, cfg.vocab)
    bat.submit(Request(rid=99, prompt=warm, max_new=1))
    bat.step()
    assert bat.alloc.used_count == 0 and bat.prefill_traces == 1
    bat.prefix_hit_pages = bat.prefix_miss_pages = bat.chunk_prefill_calls = 0
    for i, p in enumerate(_prompts(cfg, [8] * n_req, seed=7, prefix=shared)):
        bat.submit(Request(rid=i, prompt=p, max_new=gen))
    t0 = time.perf_counter()
    bat._admit()                                # admissions ONLY: no decode
    prefill_s = time.perf_counter() - t0        # (decode would add its own
    stats = bat.kv_stats()                      # first-call compile time)
    ratio = stats["kv_bytes_physical"] / max(stats["kv_bytes_logical"], 1)
    out = [row("serve/prefix_hit_rate", 100 * bat.prefix_hit_rate,
               f"unit=percent hit_pages={bat.prefix_hit_pages} "
               f"of={bat.prefix_hit_pages + bat.prefix_miss_pages}"),
           row("serve/kv_bytes_logical_vs_physical", 100 * ratio,
               f"unit=percent physical={stats['kv_bytes_physical']} "
               f"logical={stats['kv_bytes_logical']} "
               f"shared_pages={stats['pages_shared']}"),
           row("serve/chunked_prefill_tick",
               prefill_s / max(bat.chunk_prefill_calls, 1) * 1e6,
               f"chunks={bat.chunk_prefill_calls} traces={bat.prefill_traces} "
               f"(leader 3 + 3 hits x 1; no-sharing would be 12)")]
    bat.run()
    return out


def engine_rows(cfg, params, tiny: bool = False):
    """Engine-seam metrics (deterministic; the CI smoke gate reads them):
      * serve/batched_prefill_tick — mean wall time of one BATCHED
        multi-slot chunk-prefill step on a 4-request burst. The derived
        column carries steps/chunks/traces: lockstep batching launches
        max-chunks steps (3) for sum-of-chunks work items (9) under ONE
        compiled shape (traces=1 — the gate asserts it);
      * serve/preemption_recovery_tick — mean decode-tick wall time of an
        oversubscribed-pool run (3 requests x 3 worst-case pages through a
        6-page pool): the gate asserts every request completes its full
        budget with >= 1 preemption."""
    from repro.quant import linear as Q
    from repro.runtime.batcher import Request

    out = []
    # batched prefill burst: 4 requests, no sharing, 2-3 chunks each.
    # Warm the (single) compiled shape with a throwaway admission, then
    # time the burst's admissions only (no decode in the window).
    bat = _serve_batcher(cfg, params, Q.FP,
                         _prompts(cfg, [72], seed=9), 1,
                         n_slots=4, max_len=128)
    bat.step()                                  # warm + retire at admission
    bat.chunk_prefill_calls = 0
    bat.runner.prefill_steps = 0
    for i, p in enumerate(_prompts(cfg, [40, 50, 60, 70], seed=10)):
        bat.submit(Request(rid=10 + i, prompt=p, max_new=2))
    t0 = time.perf_counter()
    bat._admit()                                # the whole burst, batched
    prefill_s = time.perf_counter() - t0
    out.append(row("serve/batched_prefill_tick",
                   prefill_s / max(bat.prefill_steps, 1) * 1e6,
                   f"steps={bat.prefill_steps} "
                   f"chunks={bat.chunk_prefill_calls} "
                   f"traces={bat.prefill_traces} (sequential would launch "
                   f"{bat.chunk_prefill_calls} calls)"))
    bat.run()
    # preemption recovery: pool of 6 pages, three 2-page prompts that each
    # grow past a page boundary (worst case 3 pages each = 9 > 6): the
    # engine must preempt, recompute on readmit, and complete everything.
    gen = 10
    bat = _serve_batcher(cfg, params, Q.FP,
                         _prompts(cfg, [55, 58, 61], seed=11), gen,
                         n_slots=3, max_len=128, n_pages=6, preempt=True)
    bat.step()                                  # admit + compile the decode
    us_tick = _timed_ticks(bat, 200)            # runs to completion
    bat.run()
    done = sum(len(r.out_tokens) == gen for r in bat.finished)
    out.append(row("serve/preemption_recovery_tick", us_tick,
                   f"preempted={bat.preemptions} "
                   f"recomputed={bat.recomputed_tokens} "
                   f"completed={done} of=3 pool=6pages"))
    return out


def fused_rows(cfg, params, n_slots, max_len, tiny: bool = False):
    """Fused paged-attention rows (the CI smoke gate reads the first two):
      * serve/decode_tick_fused — decode-tick latency of the packed+fused
        engine; the derived column carries tokens_match vs a packed+unfused
        engine on the same workload at fp32 compute (exact greedy-token
        parity is only well-posed at fp32 — the kernel's online softmax and
        the full softmax differ by ulps, and bf16 argmax amplifies them);
      * serve/kv_bytes_per_slot_packed4 — per-slot bytes of the nibble
        pool (4.25 bits/elt) at the SAME n_slots/max_len as the paged/
        packed rows above, so the gate's ratio vs the bf16 paged row is
        pure storage width (floor 4.25/16 ~ 0.27);
      * gemm/paged_attn_fused_vs_unfused — kernel-level wall time of one
        fused Pallas call vs the gathered-dequant jnp path on one decode
        shape (interpret-mode correctness number on CPU; the structural
        win — K/V never materialise at bf16 width — is in the bits)."""
    import dataclasses

    from repro.core import bbfp as B
    from repro.kernels import paged_attention as PA
    from repro.models import attention as A
    from repro.models import model as M
    from repro.quant import linear as Q

    kvq = Q.QuantConfig(kv_cache="BBFP(6,3)")
    cfg32 = dataclasses.replace(cfg, compute_dtype=jnp.float32)
    params32 = M.init(cfg32, jax.random.PRNGKey(3))
    f_slots, f_len, gen = (2, 64, 6) if tiny else (3, 96, 10)
    prompts = _prompts(cfg32, [5 + 7 * i for i in range(f_slots)], seed=12)

    def engine(paged_attn):
        bat = _serve_batcher(cfg32, params32, kvq, prompts, gen,
                             n_slots=f_slots, max_len=f_len,
                             kv_storage="packed", paged_attn=paged_attn)
        bat.step()                              # admit + compile the decode
        us = _timed_ticks(bat, 4 if tiny else 8)
        bat.run()
        toks = {r.rid: [int(t) for t in r.out_tokens] for r in bat.finished}
        return us, toks

    us_f, toks_f = engine("fused")
    _, toks_u = engine("unfused")
    out = [row("serve/decode_tick_fused", us_f,
               f"slots={f_slots} tokens_match={toks_f == toks_u} "
               f"vs=unfused_jnp compute=fp32 kvq=BBFP(6_3)")]
    # packed4 byte accounting at the serving_rows pool sizing (same cfg,
    # n_slots, max_len, default n_pages) so the packed4/paged ratio is
    # storage width alone; BBFP(2,1) is the widest nibble-codable member
    kvq4 = Q.QuantConfig(kv_cache="BBFP(2,1)")
    bat4 = _serve_batcher(cfg, params, kvq4,
                          _prompts(cfg, [5 + 7 * i for i in range(n_slots)],
                                   seed=4), 2,
                          n_slots=n_slots, max_len=max_len,
                          kv_storage="packed4", paged_attn="fused")
    bat4.step()                                 # fused decode actually runs
    stats = bat4.kv_stats()
    bat4.run()
    out.append(row("serve/kv_bytes_per_slot_packed4",
                   stats["kv_bytes_per_slot"],
                   "unit=bytes (store/slots) bits/elt=4.25"))
    # kernel-level fused-vs-unfused on one decode shape: a 1-slot pool of
    # full pages, query at the last row (everything live, no masking skew)
    kh, g, hd = cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim
    page, n_pg = 32, (2 if tiny else 4)
    t = n_pg * page
    fmt = B.parse_format("BBFP(6,3)")
    proto = B.pack_kv(jnp.zeros((1, 1, kh, hd)), fmt)
    pool = lambda leaf: jnp.zeros((n_pg + 1,) + (page,) + leaf.shape[2:],
                                  jnp.int8)
    bt = jnp.arange(n_pg, dtype=jnp.int32)[None]
    rows_k = jax.random.normal(jax.random.PRNGKey(13), (1, t, kh, hd))
    rows_v = jax.random.normal(jax.random.PRNGKey(14), (1, t, kh, hd))
    zero = jnp.zeros((1,), jnp.int32)
    kp = A._paged_append({"q": pool(proto["q"]), "exp": pool(proto["exp"])},
                         bt, zero, rows_k, fmt)
    vp = A._paged_append({"q": pool(proto["q"]), "exp": pool(proto["exp"])},
                         bt, zero, rows_v, fmt)
    q = jax.random.normal(jax.random.PRNGKey(15), (1, 1, kh, g, hd),
                          jnp.float32)
    pos, win = jnp.asarray([t - 1]), jnp.asarray(t + 1, jnp.int32)
    us_fk = time_us(lambda: PA.paged_attention(q, kp, vp, bt, pos, win,
                                               fmt=fmt))

    @jax.jit
    def unfused(q, kp, vp):
        k = A._paged_view(kp, bt, fmt, jnp.float32)
        v = A._paged_view(vp, bt, fmt, jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, k) / (hd ** 0.5)
        where = (jnp.arange(t) <= t - 1)[None, None, None, None, :]
        p = Q.qsoftmax(s, Q.FP, axis=-1, where=where)
        return jnp.einsum("bkgqs,bskd->bqkgd", p, v)

    us_uk = time_us(unfused, q, kp, vp)
    out.append(row("gemm/paged_attn_fused_vs_unfused", us_fk,
                   f"unfused_us={us_uk:.1f} pages={n_pg} page={page} "
                   f"kh={kh} hd={hd} kv_bits/elt=8.25 (view never hits bf16)"))
    return out


def fused_tp_rows(cfg, tiny: bool = False):
    """Fused paged attention under tensor parallelism: the page pool is
    sharded over the mesh's "model" axis and each device runs the Pallas
    kernel on its local pages, merged with a flash-decoding log-sum-exp
    (models/attention.py). Rows (emitted only with >= 2 devices — the
    sharded-serving CI job forces 8 via XLA_FLAGS; a 1-device artifact
    omits them, keying their gates off):
      * serve/decode_tick_fused_tp2 — TP=2 fused decode-tick latency; the
        derived column carries greedy-token parity vs the TP=1 fused
        engine on the same packed workload (fp32 compute, where exact
        parity is well-posed) plus the per-shard byte split;
      * serve/kv_bytes_per_shard_packed4_tp2 — per-shard bytes of the
        nibble pool under page-dim sharding: sub-byte KV composes with TP
        (head-dim sharding never supported packed4)."""
    if len(jax.devices()) < 2:
        return []
    import dataclasses

    from repro.launch.mesh import make_serving_mesh
    from repro.models import model as M
    from repro.quant import linear as Q
    from repro.runtime.batcher import ContinuousBatcher, Request

    cfg32 = dataclasses.replace(cfg, compute_dtype=jnp.float32)
    params32 = M.init(cfg32, jax.random.PRNGKey(3))
    gen = 6 if tiny else 10
    prompts = _prompts(cfg32, [5 + 7 * i for i in range(3)], seed=16)

    def drive(mesh, storage, kvq):
        bat = ContinuousBatcher(cfg32, params32, kvq, n_slots=3, max_len=96,
                                n_pages=40, kv_storage=storage,
                                paged_attn="fused", mesh=mesh)
        for i, p in enumerate(prompts):
            bat.submit(Request(rid=i, prompt=p, max_new=gen))
        bat.step()                              # admit + compile the decode
        us = _timed_ticks(bat, 4 if tiny else 8)
        bat.run()
        toks = {r.rid: [int(t) for t in r.out_tokens] for r in bat.finished}
        return toks, bat.kv_stats(), us

    kvq = Q.QuantConfig(kv_cache="BBFP(6,3)")
    ref, _, _ = drive(None, "packed", kvq)
    got, st, us_tick = drive(make_serving_mesh(tp=2), "packed", kvq)
    out = [row("serve/decode_tick_fused_tp2", us_tick,
               f"tokens_match={got == ref} kv_shards={st['kv_shards']} "
               f"shard_bytes={st['kv_store_bytes_per_shard']} "
               f"global_bytes={st['kv_store_bytes']} "
               f"compute=fp32 storage=packed")]
    _, st4, _ = drive(make_serving_mesh(tp=2), "packed4",
                      Q.QuantConfig(kv_cache="BBFP(2,1)"))
    out.append(row("serve/kv_bytes_per_shard_packed4_tp2",
                   st4["kv_store_bytes_per_shard"],
                   f"unit=bytes kv_shards={st4['kv_shards']} "
                   f"global_bytes={st4['kv_store_bytes']} bits/elt=4.25"))
    return out


def main(argv=None):
    import argparse

    from benchmarks.common import write_bench_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shapes (seconds instead of minutes)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a BENCH_*.json artifact")
    args = ap.parse_args(argv)
    rows = run(tiny=args.tiny)
    print("name,us_per_call,derived")
    for r in rows:
        print(r, flush=True)
    if args.json:
        write_bench_json(rows, args.json, args.tiny)


if __name__ == "__main__":
    main()
