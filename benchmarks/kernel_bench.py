"""Kernel micro-benchmarks: Pallas bbfp_matmul (interpret mode on CPU) and
the jnp reference path, plus the roofline-relevant arithmetic intensity of
the BBFP GEMM (int8 path eligibility per format).

Standalone CLI for the CI bench-smoke job (tiny shapes, JSON artifact so the
perf trajectory accumulates one BENCH_*.json per commit):

  PYTHONPATH=src python -m benchmarks.kernel_bench --tiny --json BENCH_kernel.json
"""
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us
from repro.core import bbfp as B
from repro.kernels import ops, ref


def run(tiny: bool = False):
    m, k, n = (64, 128, 64) if tiny else (256, 512, 256)
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    out = []
    for fmt in ["BBFP(4,2)", "BBFP(6,3)", "BFP4", "INT8"]:
        us_ref = time_us(jax.jit(lambda a, b, f=fmt: ref.bbfp_matmul_ref(a, b, f)), a, b)
        f = B.parse_format(fmt)
        int8 = B.folded_max(f) <= 127
        out.append(row(f"kernel/matmul_ref_{fmt}", us_ref,
                       f"int8_mxu_path={int8}"))
    us_k = time_us(lambda: ops.bbfp_matmul(a, b, "BBFP(4,2)"))
    out.append(row("kernel/matmul_pallas_interpret_BBFP(4,2)", us_k,
                   "correctness path; TPU perf via BlockSpec tiling"))
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 512) if tiny else (64, 4096))
    us_l = time_us(lambda: ops.lut_apply(x, "exp"))
    out.append(row("kernel/lut_exp_pallas_interpret", us_l, ""))
    return out


def main(argv=None):
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shapes (seconds instead of minutes)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a BENCH_*.json artifact")
    args = ap.parse_args(argv)
    rows = run(tiny=args.tiny)
    print("name,us_per_call,derived")
    for r in rows:
        print(r, flush=True)
    if args.json:
        recs = []
        for r in rows:
            # format names carry commas ("BBFP(4,2)") — split from the right
            name, us, derived = r.rsplit(",", 2)
            recs.append({"name": name, "us_per_call": float(us), "derived": derived})
        payload = {"commit": os.environ.get("GITHUB_SHA", ""),
                   "tiny": args.tiny, "rows": recs}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
