"""Table V: nonlinear-unit efficiency. ADP/EDP are ASIC metrics; the TPU
re-derivation is (a) wall-time of the LUT unit vs float transcendental on
this host, (b) arithmetic-intensity: the LUT path does ZERO transcendental
flops — one gather + fixed-point post-ops per element — which is the
mechanism behind the paper's ~30x efficiency over the high-precision unit."""
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us
from repro.core import bbfp as B
from repro.core import nonlinear as NL


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 2048)) * 3
    sm_fp = jax.jit(lambda x: jax.nn.softmax(x, -1))
    sm_lut = jax.jit(lambda x: NL.softmax_lut(x, fmt=B.BBFP105))
    si_fp = jax.jit(jax.nn.silu)
    si_lut = jax.jit(lambda x: NL.silu_lut(x, fmt=B.BBFP105))
    out = [
        row("table5/softmax_fp32", time_us(sm_fp, x), "transcendental exp"),
        row("table5/softmax_lut_bbfp", time_us(sm_lut, x),
            "segmented LUT, 0 transcendental flops"),
        row("table5/silu_fp32", time_us(si_fp, x), ""),
        row("table5/silu_lut_bbfp", time_us(si_lut, x), ""),
    ]
    spec = NL.get_lut("exp", B.BBFP105)
    out.append(row("table5/lut_vmem_bytes", 0.0, spec.table.nbytes))
    out.append(row("table5/subtables", 0.0,
                   f"exp={NL.get_lut('exp', B.BBFP105).n_subtables};"
                   f"silu={NL.get_lut('one_plus_exp_neg', B.BBFP105).n_subtables}"
                   f" (paper: 18 softmax, 24 SiLU)"))
    return out
