"""Deterministic regression gates over BENCH_*.json artifacts.

This script owns EVERY bench gate that CI asserts (previously an inline
heredoc in .github/workflows/ci.yml); it runs identically in CI and
locally:

  PYTHONPATH=src python benchmarks/check_bench_gates.py \
      --json BENCH_kernel_abc.json --json BENCH_serving_abc.json

Every gate reads only DETERMINISTIC derived counters (byte accounting,
page dedup ratios, host-side engine counters, token-parity booleans) —
never wall-clock timings — so a gate failure is always a real
regression, not shared-runner noise.

Gates are keyed by row presence: a gate runs iff its rows appear in the
artifact, so one script checks both the kernel bench and the serving
bench. A file that triggers NO gate fails loudly (schema drift must not
silently disable gating).

Stdlib-only on purpose: the gate-logic unit tests (tests/
test_bench_gates.py) exercise synthetic pass/fail fixtures without
importing jax or the repro package.
"""
from __future__ import annotations

import argparse
import json
import sys


class GateFailure(AssertionError):
    """A deterministic bench invariant regressed."""


def _require(cond: bool, msg: str):
    if not cond:
        raise GateFailure(msg)


def _derived(s: str) -> dict:
    """The derived column's ``k=v`` tokens (same format benchmarks.common
    emits; free-text tokens are ignored)."""
    return dict(kv.split("=", 1) for kv in s.split() if "=" in kv)


def load_rows(path: str):
    """-> (values: name->us_per_call, derived: name->{k: v})."""
    with open(path) as f:
        payload = json.load(f)
    vals = {r["name"]: r["us_per_call"] for r in payload["rows"]}
    der = {r["name"]: _derived(r.get("derived", "")) for r in payload["rows"]}
    return vals, der


# -- kernel-bench gates ------------------------------------------------------

def gate_packed_kv(vals, der):
    """Packed-KV byte accounting: int8 codes + int8 per-32-block exponents
    vs bf16 pages floors at 8.25/16 ~ 0.52 (0.53 at the smoke head_dim);
    the packing must never silently regress past 0.55x of the fp store."""
    fp = vals["serve/kv_bytes_per_slot_paged"]
    pk = vals["serve/kv_bytes_per_slot_packed"]
    ratio = pk / fp
    print(f"  packed/fp KV bytes per slot: {pk:.0f}/{fp:.0f} = {ratio:.4f}")
    _require(ratio <= 0.55, f"packed KV regressed: {ratio:.4f} > 0.55")


def gate_packed4_kv(vals, der):
    """Nibble-packed KV byte accounting: two 4-bit codes per byte + int8
    per-32-block exponents floors at 4.25/16 ~ 0.27 of the bf16 paged
    pool; the sub-byte packing must never silently regress past 0.30x."""
    fp = vals["serve/kv_bytes_per_slot_paged"]
    p4 = vals["serve/kv_bytes_per_slot_packed4"]
    ratio = p4 / fp
    print(f"  packed4/fp KV bytes per slot: {p4:.0f}/{fp:.0f} = {ratio:.4f}")
    _require(ratio <= 0.30, f"packed4 KV regressed: {ratio:.4f} > 0.30")


def gate_fused_parity(vals, der):
    """The fused Pallas paged-attention engine must be greedy-token
    identical to the unfused gathered-dequant path on the same packed
    workload (both at fp32 compute, where exact parity is well-posed)."""
    fp = der["serve/decode_tick_fused"]
    print(f"  fused parity: tokens_match={fp['tokens_match']} "
          f"slots={fp['slots']}")
    _require(fp["tokens_match"] == "True",
             "fused paged attention diverged from the unfused jnp path")


def gate_prefix_cache(vals, der):
    """A 4-request workload sharing a 64-token (2-page) prefix must store
    each shared page exactly once — 3 followers x 2 pages deduped out of
    12 logical prompt pages puts physical/logical at 50% (no-sharing
    baseline = 100) and the page hit rate at 50%."""
    dedup = vals["serve/kv_bytes_logical_vs_physical"]
    hits = vals["serve/prefix_hit_rate"]
    print(f"  prefix cache: physical/logical = {dedup:.1f}%, "
          f"hit rate = {hits:.1f}%")
    _require(dedup <= 60.0,
             f"shared pages not deduped: physical/logical {dedup:.1f}% > 60%")
    _require(hits >= 45.0, f"prefix hit rate regressed: {hits:.1f}% < 45%")


def gate_batched_prefill(vals, der):
    """Batched multi-slot chunked prefill must keep ONE compiled prefill
    shape while launching fewer lockstep steps than per-request chunks."""
    bp = der["serve/batched_prefill_tick"]
    print(f"  batched prefill: steps={bp['steps']} chunks={bp['chunks']} "
          f"traces={bp['traces']}")
    _require(int(bp["traces"]) == 1,
             f"batched prefill retraced: {bp['traces']} shapes")
    _require(int(bp["steps"]) < int(bp["chunks"]),
             f"burst not batched: {bp['steps']} steps for "
             f"{bp['chunks']} chunks")


def gate_preemption(vals, der):
    """The oversubscribed 6-page workload must complete every request with
    at least one preemption (recompute-on-readmit actually exercised)."""
    pr = der["serve/preemption_recovery_tick"]
    print(f"  preemption recovery: preempted={pr['preempted']} "
          f"completed={pr['completed']}/{pr['of']}")
    _require(int(pr["preempted"]) >= 1, "oversubscribed pool never preempted")
    _require(pr["completed"] == pr["of"],
             f"preemption lost requests: {pr['completed']} of {pr['of']}")


# -- serving-bench gates -----------------------------------------------------

def gate_overlap_parity(vals, der):
    """The overlapped engine loop must be token-identical to the
    synchronous step() path under greedy decode AND must actually overlap
    (at least one tick planned host work while a decode was in flight)."""
    op = der["serve/overlap_parity"]
    print(f"  overlap parity: tokens_match={op['tokens_match']} "
          f"overlapped_ticks={op['overlapped_ticks']} "
          f"host_idle_ticks={op['host_idle_ticks']}")
    _require(op["tokens_match"] == "True",
             "overlapped loop diverged from synchronous decode")
    _require(int(op["overlapped_ticks"]) >= 1,
             "engine loop never overlapped host planning with device decode")


def gate_async_completion(vals, der):
    """Every stream accepted by the async server on the oversubscribed
    workload must run to completion, and the graceful drain must leave
    zero open streams."""
    ac = der["serve/async_completion"]
    print(f"  async completion: completed={ac['completed']}/{ac['of']} "
          f"drained={ac['drained']}")
    _require(ac["completed"] == ac["of"],
             f"streams lost: {ac['completed']} of {ac['of']} completed")
    _require(ac["drained"] == "True",
             "graceful drain left streams open")


def gate_fleet_affinity(vals, der):
    """Prefix-affinity routing must beat the seeded-random control on the
    pooled radix hit rate AND must not degrade the single-replica
    baseline (prefix groups land whole, so each replica's radix tree sees
    the same reuse a lone engine would). Every routed request must also
    complete — affinity is worthless if spilled/routed streams are lost."""
    fa = der["serve/fleet_affinity_hit_rate"]
    print(f"  fleet affinity: prefix={fa['prefix']} random={fa['random']} "
          f"single_replica={fa['single_replica']} "
          f"completed={fa['completed']}/{fa['of']} spills={fa['spills']}")
    _require(fa["completed"] == fa["of"],
             f"fleet lost streams: {fa['completed']} of {fa['of']}")
    _require(float(fa["prefix"]) > float(fa["random"]),
             f"prefix routing does not beat random: "
             f"{fa['prefix']} <= {fa['random']}")
    _require(float(fa["prefix"]) >= float(fa["single_replica"]) - 1e-9,
             f"fleet hit rate below the single-replica baseline: "
             f"{fa['prefix']} < {fa['single_replica']}")


def gate_failover(vals, der):
    """Replica-kill chaos: exactly one replica must die, at least one
    in-flight stream must fail over, and EVERY submitted request must
    still complete with fault-free greedy tokens (replay on the survivor
    is deterministic, so the recovered streams are token-identical)."""
    fo = der["serve/failover_recovery"]
    print(f"  failover: killed={fo['killed']} failovers={fo['failovers']} "
          f"completed={fo['completed']}/{fo['of']} "
          f"tokens_match={fo['tokens_match']}")
    _require(int(fo["killed"]) == 1,
             f"chaos kill did not land: killed={fo['killed']}")
    _require(int(fo["failovers"]) >= 1,
             "the replica kill never forced a failover")
    _require(fo["completed"] == fo["of"],
             f"failover lost requests: {fo['completed']} of {fo['of']}")
    _require(fo["tokens_match"] == "True",
             "failed-over streams diverged from the fault-free run")


def gate_shed(vals, der):
    """Depth-policy load shedding under the deterministic overload burst:
    the shed count must match the fixture's expectation exactly, and
    every non-shed stream must complete (shedding is an explicit outcome,
    not silent loss)."""
    so = der["serve/shed_overload"]
    print(f"  shed overload: shed={so['shed']} "
          f"(expected {so['expected_shed']}) "
          f"completed={so['completed']}/{so['of']} drained={so['drained']}")
    _require(so["shed"] == so["expected_shed"],
             f"shed count drifted: {so['shed']} != {so['expected_shed']}")
    _require(int(so["completed"]) == int(so["of"]) - int(so["shed"]),
             f"non-shed streams lost: {so['completed']} completed of "
             f"{so['of']} - {so['shed']} shed")
    _require(so["drained"] == "True", "shed run left streams open")


def gate_warm_restart(vals, der):
    """The radix/page snapshot round trip: the restore must bring back
    every snapshotted page, the restored engine must see MORE first-round
    prefix hits than a cold engine, and tokens must match the cold run
    (restored packed pages are bit-exact)."""
    wr = der["serve/warm_restart"]
    print(f"  warm restart: restored={wr['restored_pages']}/"
          f"{wr['snapshot_pages']} warm_hits={wr['warm_hits']} "
          f"cold_hits={wr['cold_hits']} hit_rate={wr['hit_rate']} "
          f"tokens_match={wr['tokens_match']}")
    _require(int(wr["snapshot_pages"]) > 0, "snapshot captured no pages")
    _require(wr["restored_pages"] == wr["snapshot_pages"],
             f"restore dropped pages: {wr['restored_pages']} of "
             f"{wr['snapshot_pages']}")
    _require(int(wr["warm_hits"]) > int(wr["cold_hits"]),
             f"warm restart produced no extra first-round hits: "
             f"{wr['warm_hits']} <= {wr['cold_hits']}")
    _require(float(wr["hit_rate"]) > 0.0, "restored hit rate is zero")
    _require(wr["tokens_match"] == "True",
             "warm-restarted engine diverged from the cold run")


def gate_tp_parity(vals, der):
    """A TP=2 engine (params + page pools sharded over the model axis)
    must produce greedy tokens identical to the single-device engine, and
    the head-sharded pool must actually split: per-shard bytes x shards
    == global bytes. The row only exists in artifacts produced with >= 2
    devices (the sharded-serving job), so 1-device runs skip this gate."""
    tp = der["serve/decode_tick_tp2"]
    print(f"  tp parity: tokens_match={tp['tokens_match']} "
          f"kv_shards={tp['kv_shards']} shard_bytes={tp['shard_bytes']} "
          f"global_bytes={tp['global_bytes']}")
    _require(tp["tokens_match"] == "True",
             "TP=2 decode diverged from the single-device engine")
    _require(int(tp["kv_shards"]) >= 2,
             f"page pool not sharded: kv_shards={tp['kv_shards']}")
    _require(int(tp["shard_bytes"]) * int(tp["kv_shards"])
             == int(tp["global_bytes"]),
             f"pool bytes not split across shards: {tp['shard_bytes']} x "
             f"{tp['kv_shards']} != {tp['global_bytes']}")


def gate_fused_tp_parity(vals, der):
    """The page-dim-sharded fused engine (each device runs the Pallas
    kernel over its local page-pool shard; partials merged with a
    flash-decoding log-sum-exp) must be greedy-token identical to the
    TP=1 fused engine at fp32, and the pool must actually split: per-shard
    bytes x shards == global bytes. The row only exists in artifacts
    produced with >= 2 devices (the sharded-serving job)."""
    ft = der["serve/decode_tick_fused_tp2"]
    print(f"  fused tp parity: tokens_match={ft['tokens_match']} "
          f"kv_shards={ft['kv_shards']} shard_bytes={ft['shard_bytes']} "
          f"global_bytes={ft['global_bytes']}")
    _require(ft["tokens_match"] == "True",
             "fused TP=2 decode diverged from the TP=1 fused engine")
    _require(int(ft["kv_shards"]) >= 2,
             f"fused page pool not sharded: kv_shards={ft['kv_shards']}")
    _require(int(ft["shard_bytes"]) * int(ft["kv_shards"])
             == int(ft["global_bytes"]),
             f"fused pool bytes not split across shards: "
             f"{ft['shard_bytes']} x {ft['kv_shards']} != "
             f"{ft['global_bytes']}")


def gate_packed4_tp_shards(vals, der):
    """Sub-byte (nibble) KV under page-dim TP: the packed4 pool must shard
    like any other storage format — per-shard bytes x shards == global —
    proving the 4.25-bit pool composes with tensor parallelism (head-dim
    sharding never supported packed4)."""
    p4 = der["serve/kv_bytes_per_shard_packed4_tp2"]
    shard = vals["serve/kv_bytes_per_shard_packed4_tp2"]
    print(f"  packed4 tp shards: shard_bytes={shard:.0f} "
          f"kv_shards={p4['kv_shards']} global_bytes={p4['global_bytes']}")
    _require(shard > 0, "packed4 per-shard bytes is zero")
    _require(int(p4["kv_shards"]) >= 2,
             f"packed4 pool not sharded: kv_shards={p4['kv_shards']}")
    _require(int(shard) * int(p4["kv_shards"]) == int(p4["global_bytes"]),
             f"packed4 pool bytes not split across shards: {shard:.0f} x "
             f"{p4['kv_shards']} != {p4['global_bytes']}")


# gate -> the rows whose presence makes it applicable
GATES = [
    (gate_packed_kv, ("serve/kv_bytes_per_slot_paged",
                      "serve/kv_bytes_per_slot_packed")),
    (gate_packed4_kv, ("serve/kv_bytes_per_slot_paged",
                       "serve/kv_bytes_per_slot_packed4")),
    (gate_fused_parity, ("serve/decode_tick_fused",)),
    (gate_prefix_cache, ("serve/kv_bytes_logical_vs_physical",
                         "serve/prefix_hit_rate")),
    (gate_batched_prefill, ("serve/batched_prefill_tick",)),
    (gate_preemption, ("serve/preemption_recovery_tick",)),
    (gate_overlap_parity, ("serve/overlap_parity",)),
    (gate_async_completion, ("serve/async_completion",)),
    (gate_fleet_affinity, ("serve/fleet_affinity_hit_rate",)),
    (gate_failover, ("serve/failover_recovery",)),
    (gate_shed, ("serve/shed_overload",)),
    (gate_warm_restart, ("serve/warm_restart",)),
    (gate_tp_parity, ("serve/decode_tick_tp2",)),
    (gate_fused_tp_parity, ("serve/decode_tick_fused_tp2",)),
    (gate_packed4_tp_shards, ("serve/kv_bytes_per_shard_packed4_tp2",)),
]


def check_file(path: str) -> list[str]:
    """Run every applicable gate over one artifact; -> failure messages."""
    vals, der = load_rows(path)
    print(f"{path}:")
    failures, ran = [], 0
    for fn, needed in GATES:
        if not all(n in vals for n in needed):
            continue
        ran += 1
        try:
            fn(vals, der)
        except GateFailure as e:
            failures.append(f"{path}: {fn.__name__}: {e}")
            print(f"  FAIL: {e}")
    if ran == 0:
        failures.append(f"{path}: no gate matched any row — schema drift? "
                        f"(rows: {sorted(vals)[:5]}...)")
    else:
        print(f"  {ran} gate(s) ran, {len(failures)} failed")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="append", required=True, metavar="PATH",
                    help="BENCH_*.json artifact to gate (repeatable)")
    args = ap.parse_args(argv)
    failures = []
    for path in args.json:
        failures += check_file(path)
    if failures:
        print(f"\n{len(failures)} gate failure(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("all bench gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
