"""Fig. 3: impact of shared-exponent selection on BBFP(4,2) quantisation
error. Paper claim: max-(m-o) best; max-3 catastrophic; max-1 worse."""
import jax

from benchmarks.common import row, time_us
from repro.core import bbfp as B
from repro.core import error as E

STRATS = [("max-3", -1), ("max-(m-o)", 0), ("max-1", 1), ("max", 2)]


def run():
    x = E.llm_activation_sample(jax.random.PRNGKey(0), (2048, 512))
    out = []
    mses = {}
    for name, off in STRATS:
        fmt = B.QuantFormat("bbfp", 4, 2, exponent_offset=off)
        us = time_us(lambda x=x, f=fmt: E.empirical_mse(x, f))
        mse = float(E.empirical_mse(x, fmt))
        mses[name] = mse
        out.append(row(f"fig3/{name}", us, f"mse={mse:.3e}"))
    ok = mses["max-(m-o)"] < mses["max-1"] < mses["max-3"] and \
        mses["max-(m-o)"] < mses["max"]
    out.append(row("fig3/ordering_matches_paper", 0.0, ok))
    return out
