"""Fig. 4 + Algorithm 1: overlap width selection for width-6 BBFP.
Paper claim: PPL vs overlap is U-shaped-ish; Algo 1 balances PPL against
hardware overhead via the weight w."""
import jax

from benchmarks.common import get_outlier_tiny_lm, eval_ppl, row
from repro.core import bbfp as B
from repro.core.overlap import overhead, select_overlap_width
from repro.quant import linear as Q


def run():
    cfg, params = get_outlier_tiny_lm()

    def ppl_fn(fmt: B.QuantFormat) -> float:
        return eval_ppl(cfg, params,
                        Q.QuantConfig(linear=fmt.name, nonlinear="none"),
                        n_batches=4)

    out = []
    ppls = {}
    for o in range(0, 6):
        fmt = B.QuantFormat("bbfp", 6, o)
        p = ppl_fn(fmt)
        ppls[o] = p
        out.append(row(f"fig4/BBFP(6,{o})", 0.0,
                       f"ppl={p:.3f};overhead={overhead(fmt):.2f}"))
    for w in (0.0, 0.5, 0.9):
        best, diag = select_overlap_width(lambda f: ppls[f.overlap], 6, w=w)
        out.append(row(f"fig4/algo1_w={w}", 0.0, f"best_o={best}"))
    return out
