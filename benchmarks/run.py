"""Benchmark harness: one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV and writes results/benchmarks.csv.
"""
from __future__ import annotations

import os
import traceback

MODULES = [
    "benchmarks.fig1b_nonlinear_share",
    "benchmarks.table1_memeff",
    "benchmarks.fig3_shared_exponent",
    "benchmarks.table3_area_proxy",
    "benchmarks.fig9_energy_proxy",
    "benchmarks.kernel_bench",
    "benchmarks.table5_nonlinear_eff",
    "benchmarks.table2_linear_ppl",
    "benchmarks.table4_nonlinear",
    "benchmarks.fig4_overlap",
    "benchmarks.fig8_tradeoff",
]


def main() -> None:
    import importlib
    rows = ["name,us_per_call,derived"]
    print(rows[0])
    for mod_name in MODULES:
        try:
            mod = importlib.import_module(mod_name)
            for r in mod.run():
                rows.append(r)
                print(r, flush=True)
        except Exception:
            traceback.print_exc()
            rows.append(f"{mod_name},0.0,ERROR")
            print(rows[-1], flush=True)
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "benchmarks.csv")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write("\n".join(rows) + "\n")


if __name__ == "__main__":
    main()
