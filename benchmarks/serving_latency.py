"""Closed-loop serving-latency benchmark: the async front door under a
seeded Poisson arrival-rate sweep, emitting TTFT / TPOT / goodput
percentile rows — plus the deterministic engine-overlap and streaming-
completion rows the CI gate asserts — into the shared BENCH_*.json schema.

Sections (one ModelRunner is shared by every batcher so the decode and
chunk-prefill shapes compile ONCE; later sections time warm code):

  * serve/overlap_parity — the overlapped engine loop
    (``step_overlapped``: host plans tick N+1 while tick N's decode is in
    flight, ``jax.block_until_ready`` only at the stream edge) must be
    TOKEN-IDENTICAL to the synchronous ``step()`` path under greedy
    decode, and must actually overlap: the derived column carries
    ``tokens_match`` / ``overlapped_ticks`` / ``host_idle_ticks`` (gated:
    match == True, overlapped_ticks >= 1). The value column is the warm
    mean overlapped-tick wall time.
  * serve/async_completion — the asyncio server on an OVERSUBSCRIBED
    workload (2x more streams than decode slots, mixed SLO classes):
    every accepted stream must run to completion and the graceful drain
    must leave zero open streams (gated: completed == of, drained=True).
  * serve/{ttft,tpot}_{p50,p95}_rps{R} + serve/goodput_rps{R} — the
    closed-loop sweep: Poisson arrivals (seeded, deterministic schedule)
    over the shared-prefix workload from kernel_bench's ``_prompts`` at
    each rate R; the sweep waits for each rate to fully drain before the
    next. Timing rows track the trajectory; they are NOT gated (wall
    time on shared CI runners is noise) — the gates read only the
    deterministic derived counters above.
  * serve/fleet_affinity_hit_rate — a 2-replica EngineFleet under
    prefix-affinity routing vs the seeded-random control vs a
    single-replica baseline on a grouped shared-prefix workload (gated:
    prefix > random, prefix >= single-replica).
  * serve/decode_tick_tp2 — TP=2 vs TP=1 greedy token parity + the
    per-shard page-pool byte split; emitted only when the host exposes
    >= 2 devices (the sharded-serving CI job forces 8 with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), so the
    1-device bench-smoke artifact omits the row and its gate.
  * serve/failover_recovery — a 2-replica fleet under a DETERMINISTIC
    chaos schedule (replica 0 killed mid-decode): every submitted stream
    must still complete via transparent failover, token-identical to a
    fault-free run (gated: killed == 1, failovers >= 1, completed == of,
    tokens_match == True).
  * serve/shed_overload — depth-policy load shedding under a
    deterministic overload burst (submissions staged before the engine
    loop starts, so the shed decision depends only on depth): the shed
    count must equal the fixture's expectation and every non-shed stream
    must complete (gated).
  * serve/warm_restart — the radix/page snapshot round trip: a fresh
    engine restored from a served donor's ``snapshot_kv`` must report
    prefix hits on its FIRST admission round with token parity against a
    cold run (gated: restored > 0, warm hits > cold hits,
    tokens_match == True).

  PYTHONPATH=src python -m benchmarks.serving_latency --tiny \
      --json BENCH_serving.json
"""
import asyncio
import time

import jax

from benchmarks.common import row, write_bench_json
from benchmarks.kernel_bench import _prompts, _serve_batcher

DEADLINE_S = 300.0      # generous CI budget: goodput counts completions
#                         within it, but no gate reads the 'good' count


def _drain(bat, overlapped: bool):
    """Run a batcher to empty via either loop; returns (tokens, ticks)."""
    fin, ticks = bat.run_overlapped() if overlapped else bat.run()
    return {r.rid: list(r.out_tokens) for r in fin}, ticks


def overlap_parity_rows(cfg, params, runner, tiny: bool):
    """Sync-vs-overlapped token parity + the overlap proof counters."""
    from repro.quant import linear as Q

    gen = 8 if tiny else 16
    # 6 requests onto 4 slots: the queued tail keeps phase A busy (real
    # admission planning concurrent with the in-flight decode), so the
    # overlap counter — not just the idle one — must tick
    lens = [40, 50, 60, 70, 30, 44]
    mk = lambda: _serve_batcher(cfg, params, Q.FP,                  # noqa: E731
                                _prompts(cfg, lens, seed=21), gen,
                                n_slots=4, max_len=128, runner=runner)
    sync_toks, _ = _drain(mk(), overlapped=False)   # pays the compiles
    bat = mk()
    t0 = time.perf_counter()
    ov_toks, ticks = _drain(bat, overlapped=True)   # warm: timed
    us_tick = (time.perf_counter() - t0) / max(ticks, 1) * 1e6
    return [row("serve/overlap_parity", us_tick,
                f"tokens_match={sync_toks == ov_toks} "
                f"overlapped_ticks={bat.overlapped_ticks} "
                f"host_idle_ticks={bat.host_idle_ticks} "
                f"decode_calls={bat.decode_calls}")]


def async_completion_rows(cfg, params, runner, tiny: bool):
    """The streaming front door on an oversubscribed workload: 2x more
    requests than slots, mixed SLO classes; every stream must complete."""
    from repro.launch.server import AsyncServer, WorkItem, closed_loop
    from repro.quant import linear as Q

    n_slots, gen = 4, (6 if tiny else 10)
    n_req = 2 * n_slots
    prompts = _prompts(cfg, [10 + 9 * i for i in range(n_req)], seed=22)
    slos = ["interactive", "standard", "batch"]
    work = [WorkItem(prompt=p, max_new=gen, slo=slos[i % 3],
                     deadline_s=DEADLINE_S)
            for i, p in enumerate(prompts)]
    bat = _serve_batcher(cfg, params, Q.FP, [], gen, n_slots=n_slots,
                         max_len=128, runner=runner)

    async def go():
        srv = AsyncServer(bat)
        await srv.start()
        t0 = time.perf_counter()
        mets = await closed_loop(srv, work, rate=100.0, seed=23,
                                 timeout_s=600.0)
        dt = time.perf_counter() - t0
        await srv.shutdown(drain=True)
        return srv, mets, dt

    srv, mets, dt = asyncio.run(go())
    ctr = srv.counters()
    return [row("serve/async_completion", dt / max(len(mets), 1) * 1e6,
                f"completed={ctr['completed']} of={n_req} "
                f"drained={ctr['open_streams'] == 0} "
                f"overlapped_ticks={ctr['overlapped_ticks']} "
                f"preemptions={ctr['preemptions']}")]


def rate_sweep_rows(cfg, params, runner, tiny: bool):
    """The closed-loop TTFT/TPOT/goodput sweep over Poisson arrival rates
    on the shared-prefix workload (kernel_bench's _prompts)."""
    from repro.launch.server import (
        AsyncServer, WorkItem, closed_loop, percentile_rows,
    )
    from repro.quant import linear as Q

    rates = (4.0, 32.0) if tiny else (2.0, 8.0, 32.0)
    n_req, gen = (6, 6) if tiny else (12, 12)
    shared = jax.random.randint(jax.random.PRNGKey(6), (64,), 0, cfg.vocab)
    out = []
    for k, rate in enumerate(rates):
        prompts = _prompts(cfg, [8] * n_req, seed=7, prefix=shared)
        work = [WorkItem(prompt=p, max_new=gen, slo="standard",
                         deadline_s=DEADLINE_S) for p in prompts]
        bat = _serve_batcher(cfg, params, Q.FP, [], gen, n_slots=4,
                             max_len=128, runner=runner)

        async def go(work=work, bat=bat, rate=rate, k=k):
            srv = AsyncServer(bat)
            await srv.start()
            mets = await closed_loop(srv, work, rate=rate, seed=42 + k,
                                     timeout_s=600.0)
            await srv.shutdown(drain=True)
            return mets

        pr = percentile_rows(asyncio.run(go()))
        tag = f"rps{rate:g}"
        info = f"n={n_req} rate={rate:g} seed={42 + k} unit=us"
        out += [row(f"serve/ttft_p50_{tag}", pr["ttft_p50_us"], info),
                row(f"serve/ttft_p95_{tag}", pr["ttft_p95_us"], info),
                row(f"serve/tpot_p50_{tag}", pr["tpot_p50_us"], info),
                row(f"serve/tpot_p95_{tag}", pr["tpot_p95_us"], info),
                row(f"serve/goodput_{tag}", pr["goodput_rps"],
                    f"unit=req/s good={pr['good']} of={pr['of']} "
                    f"deadline_s={DEADLINE_S:g}")]
    return out


def tp_parity_rows(tiny: bool):
    """TP=2 vs TP=1 greedy token parity over the deterministic
    shared-prefix workload, plus the per-shard page-pool byte split.
    Emitted only when the host exposes >= 2 devices (the sharded-serving
    CI job forces 8 via XLA_FLAGS); a 1-device artifact omits the row,
    which keys its gate off. Computes in fp32: bf16 reassociation under
    resharding is percent-level and would make exact token parity
    ill-posed (see tests/test_tp_serving.py)."""
    if len(jax.devices()) < 2:
        return []
    import dataclasses

    import jax.numpy as jnp

    from repro import configs
    from repro.launch.mesh import make_serving_mesh
    from repro.models import model as M
    from repro.quant import linear as Q
    from repro.runtime import paged_kv as PK
    from repro.runtime.batcher import ContinuousBatcher, Request

    cfg = dataclasses.replace(configs.smoke_config("llama7b"),
                              compute_dtype=jnp.float32)
    key = jax.random.PRNGKey(7)
    params = M.init(cfg, key)
    gen = 6 if tiny else 12
    shared = jax.random.randint(key, (2 * PK.PAGE_SIZE,), 0, cfg.vocab)
    prompts = [jnp.concatenate(
        [shared, jax.random.randint(jax.random.fold_in(key, i),
                                    (5 + 3 * i,), 0, cfg.vocab)])
        for i in range(3)]

    def drive(mesh):
        bat = ContinuousBatcher(cfg, params, Q.FP, n_slots=4, max_len=128,
                                n_pages=40, mesh=mesh)
        for i, p in enumerate(prompts):
            bat.submit(Request(rid=i, prompt=p, max_new=gen))
        t0 = time.perf_counter()
        fin, ticks = bat.run()
        us = (time.perf_counter() - t0) / max(ticks, 1) * 1e6
        return {r.rid: list(r.out_tokens) for r in fin}, bat, us

    ref, _, _ = drive(None)
    got, bat, us_tick = drive(make_serving_mesh(tp=2))
    st = bat.kv_stats()
    return [row("serve/decode_tick_tp2", us_tick,
                f"tokens_match={got == ref} kv_shards={st['kv_shards']} "
                f"shard_bytes={st['kv_store_bytes_per_shard']} "
                f"global_bytes={st['kv_store_bytes']}")]


def fleet_affinity_rows(cfg, params, runner, tiny: bool):
    """Prefix-affinity routing proof on a grouped shared-prefix workload:
    a 2-replica fleet routed by first-page hash must keep the pooled radix
    hit rate at the single-replica level (groups land whole), beating the
    seeded-random control that splits prefix groups across replicas. All
    compared counters are host-side and deterministic; the value column is
    the prefix-routed hit rate in %."""
    import jax.numpy as jnp

    from repro.launch.router import EngineFleet
    from repro.launch.server import AsyncServer, WorkItem, closed_loop
    from repro.quant import linear as Q
    from repro.runtime import paged_kv as PK

    n_groups, per_group, gen = 4, 3, (4 if tiny else 8)
    key = jax.random.PRNGKey(11)
    work = []
    for g in range(n_groups):
        shared = jax.random.randint(jax.random.fold_in(key, g),
                                    (2 * PK.PAGE_SIZE,), 0, cfg.vocab)
        for j in range(per_group):
            tail = jax.random.randint(
                jax.random.fold_in(key, 100 + 10 * g + j), (8,), 0,
                cfg.vocab)
            work.append(WorkItem(prompt=jnp.concatenate([shared, tail]),
                                 max_new=gen, deadline_s=DEADLINE_S))

    def drive(routing, n_replicas):
        bats = [_serve_batcher(cfg, params, Q.FP, [], gen, n_slots=4,
                               max_len=128, n_pages=64, runner=runner)
                for _ in range(n_replicas)]

        async def go():
            fleet = EngineFleet([AsyncServer(b) for b in bats],
                                routing=routing, spill_threshold=None,
                                seed=5)
            await fleet.start()
            await closed_loop(fleet, work, rate=100.0, seed=31,
                              timeout_s=600.0)
            await fleet.shutdown(drain=True)
            return fleet

        return asyncio.run(go()).counters()

    pre = drive("prefix", 2)
    rnd = drive("random", 2)
    solo = drive("prefix", 1)
    rate = lambda c: c["fleet_affinity_hit_rate"]            # noqa: E731
    return [row("serve/fleet_affinity_hit_rate", rate(pre) * 100.0,
                f"unit=% prefix={rate(pre):.4f} random={rate(rnd):.4f} "
                f"single_replica={rate(solo):.4f} "
                f"completed={pre['completed']} of={len(work)} "
                f"picks={'/'.join(map(str, pre['picks']))} "
                f"spills={pre['spills']}")]


def failover_recovery_rows(cfg, params, runner, tiny: bool):
    """Replica-kill chaos on a 2-replica fleet: replica 0 dies at a fixed
    engine tick; its in-flight streams must fail over to the survivor and
    complete with fault-free greedy tokens (replay + skip-consume). The
    value column is wall time per request including the recovery."""
    from repro.launch.router import EngineFleet, prefix_replica
    from repro.launch.server import AsyncServer
    from repro.quant import linear as Q
    from repro.runtime.faults import ChaosInjector

    gen = 8 if tiny else 12
    cands = _prompts(cfg, [40 + 4 * i for i in range(10)], seed=25)
    to0 = [p for p in cands if prefix_replica(p, 2) == 0][:3]
    to1 = [p for p in cands if prefix_replica(p, 2) == 1][:3]
    prompts = to0 + to1
    ref, _ = _drain(_serve_batcher(cfg, params, Q.FP, prompts, gen,
                                   n_slots=4, max_len=128, runner=runner),
                    overlapped=False)

    async def go():
        mk = lambda: _serve_batcher(cfg, params, Q.FP, [], gen,   # noqa: E731
                                    n_slots=4, max_len=128, runner=runner)
        srv0 = AsyncServer(mk(), chaos=ChaosInjector(kill_at_tick=3))
        srv1 = AsyncServer(mk())
        fleet = EngineFleet([srv0, srv1])
        await fleet.start()
        t0 = time.perf_counter()
        streams = [fleet.submit(p, gen) for p in prompts]

        async def collect(s):
            return [t async for t in s]

        outs = await asyncio.gather(*[collect(s) for s in streams])
        dt = time.perf_counter() - t0
        await fleet.shutdown(drain=True)
        return fleet, outs, dt

    fleet, outs, dt = asyncio.run(go())
    ctr = fleet.counters()
    match = {i: o for i, o in enumerate(outs)} == ref
    killed = sum(h == "dead" for h in ctr["health"])
    return [row("serve/failover_recovery", dt / len(prompts) * 1e6,
                f"killed={killed} failovers={ctr['failovers']} "
                f"completed={ctr['completed']} of={len(prompts)} "
                f"tokens_match={match} reroutes={ctr['reroutes']}")]


def shed_overload_rows(cfg, params, runner, tiny: bool):
    """Depth-policy load shedding under a deterministic overload burst:
    every submission lands BEFORE the engine loop starts, so the queue
    depth each request sees — and hence the shed decision — is a pure
    function of submit order. batch-class past the threshold sheds; the
    interactive rider never does."""
    from repro.launch.server import AsyncServer
    from repro.quant import linear as Q

    gen, depth = (4 if tiny else 8), 2
    n_batch = 6
    prompts = _prompts(cfg, [16 + 4 * i for i in range(n_batch + 1)],
                       seed=26)
    expected_shed = n_batch - depth

    async def go():
        bat = _serve_batcher(cfg, params, Q.FP, [], gen, n_slots=4,
                             max_len=128, runner=runner)
        srv = AsyncServer(bat, shed_policy="depth", shed_depth=depth)
        streams = [srv.submit(p, gen, slo="batch")
                   for p in prompts[:n_batch]]
        streams.append(srv.submit(prompts[n_batch], gen, slo="interactive"))
        await srv.start()
        t0 = time.perf_counter()

        async def collect(s):
            try:
                return [t async for t in s]
            except Exception as e:
                return e

        outs = await asyncio.gather(*[collect(s) for s in streams])
        dt = time.perf_counter() - t0
        await srv.shutdown(drain=True)
        return srv, outs, dt

    srv, outs, dt = asyncio.run(go())
    ctr = srv.counters()
    served = sum(isinstance(o, list) and len(o) == gen for o in outs)
    return [row("serve/shed_overload", dt / len(outs) * 1e6,
                f"shed={ctr['shed']} expected_shed={expected_shed} "
                f"completed={ctr['completed']} of={len(outs)} "
                f"served={served} drained={ctr['open_streams'] == 0}")]


def warm_restart_rows(cfg, params, runner, tiny: bool):
    """The radix/page snapshot round trip: serve a shared-prefix workload,
    ``snapshot_kv`` through the checkpoint store, restore into a FRESH
    engine, and re-serve — the restored engine must report prefix hits on
    its FIRST admission round, token-identical to the cold run. The value
    column is the restore wall time."""
    import tempfile

    import jax.numpy as jnp

    from repro.quant import linear as Q
    from repro.runtime import paged_kv as PK

    gen = 5 if tiny else 10
    shared = jax.random.randint(jax.random.PRNGKey(27),
                                (2 * PK.PAGE_SIZE,), 0, cfg.vocab)
    prompts = [jnp.concatenate(
        [shared, jax.random.randint(jax.random.fold_in(
            jax.random.PRNGKey(28), i), (5 + 4 * i,), 0, cfg.vocab)])
        for i in range(3)]
    mk = lambda: _serve_batcher(cfg, params, Q.FP, prompts, gen,  # noqa: E731
                                n_slots=4, max_len=128, runner=runner)
    donor = mk()
    ref, _ = _drain(donor, overlapped=False)
    snap_dir = tempfile.mkdtemp()
    n_snap = donor.snapshot_kv(snap_dir)

    cold = mk()
    cold_toks, _ = _drain(cold, overlapped=False)

    warm = mk()
    t0 = time.perf_counter()
    n_rest = warm.restore_kv(snap_dir)
    restore_us = (time.perf_counter() - t0) * 1e6
    warm_toks, _ = _drain(warm, overlapped=False)
    return [row("serve/warm_restart", restore_us,
                f"snapshot_pages={n_snap} restored_pages={n_rest} "
                f"warm_hits={warm.prefix_hit_pages} "
                f"cold_hits={cold.prefix_hit_pages} "
                f"hit_rate={warm.prefix_hit_rate:.4f} "
                f"tokens_match={warm_toks == ref == cold_toks}")]


def run(tiny: bool = False):
    from repro import configs
    from repro.models import model as M
    from repro.quant import linear as Q
    from repro.runtime.model_runner import ModelRunner

    cfg = configs.smoke_config("llama7b")
    params = M.init(cfg, jax.random.PRNGKey(3))
    # ONE runner for every section: the decode and batched-chunk-prefill
    # shapes compile once, so later sections measure warm engine code
    runner = ModelRunner(cfg, params, Q.FP, prefill_chunk=32,
                         prefill_slots=4)
    out = []
    out += overlap_parity_rows(cfg, params, runner, tiny)
    out += async_completion_rows(cfg, params, runner, tiny)
    out += rate_sweep_rows(cfg, params, runner, tiny)
    out += fleet_affinity_rows(cfg, params, runner, tiny)
    out += failover_recovery_rows(cfg, params, runner, tiny)
    out += shed_overload_rows(cfg, params, runner, tiny)
    out += warm_restart_rows(cfg, params, runner, tiny)
    out += tp_parity_rows(tiny)
    return out


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shapes (seconds instead of minutes)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a BENCH_*.json artifact")
    args = ap.parse_args(argv)
    rows = run(tiny=args.tiny)
    print("name,us_per_call,derived")
    for r in rows:
        print(r, flush=True)
    if args.json:
        write_bench_json(rows, args.json, args.tiny)


if __name__ == "__main__":
    main()
