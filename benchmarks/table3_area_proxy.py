"""Table III: PE area across quantisation strategies.

28nm synthesis is not reproducible in software; we re-derive the paper's
RELATIVE areas from a physical arithmetic-density model
    area ~ a*m^2 (multiplier array) + b*m (partial-sum adder) + c*shift
           (flag mux/shifter, §IV.A) + d
with (a,b,c,d) fitted once to the paper's nine normalised numbers by least
squares — the deliverable is how well a 4-parameter circuit model explains
the paper's synthesis results (mean residual reported)."""
import numpy as np

from benchmarks.common import row
from repro.core import bbfp as B

PAPER_NORM = {"BFP4": 0.46, "BFP6": 0.90, "BBFP(3,1)": 0.32, "BBFP(3,2)": 0.31,
              "BBFP(4,2)": 0.49, "BBFP(4,3)": 0.47, "BBFP(6,3)": 1.00,
              "BBFP(6,4)": 0.96, "BBFP(6,5)": 0.93}

_COEF = None


def _features(fmt: B.QuantFormat):
    sh = fmt.shift if fmt.kind == "bbfp" else 0
    return [fmt.mantissa ** 2, fmt.mantissa, sh, 1.0]


def _fit():
    global _COEF
    if _COEF is None:
        X = np.array([_features(B.parse_format(n)) for n in PAPER_NORM])
        y = np.array(list(PAPER_NORM.values()))
        _COEF, *_ = np.linalg.lstsq(X, y, rcond=None)
    return _COEF


def area_model(fmt: B.QuantFormat) -> float:
    c = _fit()
    return float(max(np.dot(_features(fmt), c), 1e-3))


def run():
    out = []
    errs = []
    coef = _fit()
    norm = area_model(B.parse_format("BBFP(6,3)"))
    for n, target in PAPER_NORM.items():
        rel = area_model(B.parse_format(n)) / norm
        errs.append(abs(rel - target) / target)
        out.append(row(f"table3/{n}", 0.0,
                       f"norm_area={rel:.2f}(paper {target:.2f})"))
    out.append(row("table3/model", 0.0,
                   f"area={coef[0]:.3f}m^2{coef[1]:+.3f}m{coef[2]:+.3f}shift{coef[3]:+.3f}"))
    out.append(row("table3/mean_rel_err_vs_paper", 0.0,
                   f"{sum(errs)/len(errs):.2%}"))
    return out
