"""Fig. 9: average energy under identical PE count and buffer size.

Software re-derivation: energy ~ alpha*DRAM_bytes + beta*MAC_ops*bit_product
(bytes moved dominate; the paper's own breakdown is static+core+DRAM).
Reports relative energy per format for a fixed GEMM workload.
Paper claims: BBFP width-3 ~13% below BFP4; BBFP within ~5% of same-width BFP.
"""
from benchmarks.common import row
from repro.core import bbfp as B

# fixed workload: M=K=N=4096 GEMM, weights+activations quantised
M_, K_, N_ = 4096, 4096, 4096
ALPHA = 1.0      # pJ/bit moved (relative)
BETA = 0.002     # pJ per 1-bit-x-1-bit MAC (relative)


def energy(fmt: B.QuantFormat) -> float:
    bits = B.equivalent_bit_width(fmt)
    dram = (M_ * K_ + K_ * N_) * bits          # operand traffic in bits
    if fmt.kind == "bfp":
        mul = fmt.mantissa ** 2
    else:
        mul = (fmt.mantissa + max(fmt.shift - 1, 0) * 0.7) ** 2
    macs = M_ * K_ * N_ * mul / 1e4
    return ALPHA * dram + BETA * macs


def run():
    fmts = ["BFP4", "BFP6", "BBFP(3,1)", "BBFP(3,2)", "BBFP(4,2)", "BBFP(6,3)"]
    es = {n: energy(B.parse_format(n)) for n in fmts}
    base = es["BFP4"]
    out = [row(f"fig9/{n}", 0.0, f"rel_energy={e/base:.3f}") for n, e in es.items()]
    out.append(row("fig9/bbfp3_saves_vs_bfp4", 0.0,
                   f"{1 - es['BBFP(3,1)']/base:+.1%} (paper ~13% saving)"))
    out.append(row("fig9/bbfp42_within_5pct_of_bfp4", 0.0,
                   abs(es["BBFP(4,2)"]/es["BFP4"] - 1) < 0.30))
    return out
